//! Adapter representations, the host-side adapter store, and the
//! device-bank page cache behind the serving registry.
//!
//! All three RoAd variants share the serving representation of two
//! effective vectors (R1, R2) per adapted projection (Eq. 4); training
//! parameterizations (theta/alpha in 1/2/4-way sharing, Table 1) convert
//! through [`RoadVectors::from_theta_alpha`].  LoRA and (IA)³ adapters are
//! carried for the Figure-4 baseline comparison.
//!
//! # Virtualized adapter storage
//!
//! The paper's serving pitch is per-user adapters at near-zero batching
//! cost, which implies far more registered adapters than any fixed device
//! bank can hold.  Storage is therefore split in two:
//!
//! * [`AdapterStore`] — host-side, unbounded, name-keyed.  Registration
//!   always succeeds; this is where "thousands of trained adapters" live.
//! * [`AdapterBank`] — the device-facing `[n_slots, ...]` tensors matching
//!   the HLO bank inputs, reinterpreted as a page cache over the store.
//!
//! [`AdapterRegistry`] manages the mapping: admission pages a request's
//! adapter into a free-or-LRU-evictable bank slot
//! ([`AdapterRegistry::ensure_resident`]) and pins slots referenced by
//! in-flight decode lanes so eviction can never corrupt an active request.
//! Dirty state is tracked per slot, so re-uploads move only the rows that
//! changed ([`AdapterBank::upload_dirty`]).

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::pool::LruPager;
use crate::manifest::ModelConfigInfo;
use crate::model::{proj_dims, PROJS};
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

/// Effective serving vectors for one projection: z = r1⊗h + r2⊗ĥ.
#[derive(Clone, Debug, PartialEq)]
pub struct RoadVectors {
    pub r1: Vec<f32>,
    pub r2: Vec<f32>,
}

impl RoadVectors {
    pub fn identity(d: usize) -> RoadVectors {
        RoadVectors { r1: vec![1.0; d], r2: vec![0.0; d] }
    }

    /// Convert trainable (theta, alpha) to effective vectors.
    ///
    /// variant 1: theta/alpha `[d/2]`;  variant 2: `[d/2, 2]` row-shared;
    /// variant 4: `[d/2, 4]` all-distinct (t11, t12, t21, t22) — mirrors
    /// python/compile/kernels/ref.py exactly.
    pub fn from_theta_alpha(variant: usize, theta: &[f32], alpha: &[f32]) -> Result<RoadVectors> {
        let per = match variant {
            1 => 1,
            2 => 2,
            4 => 4,
            _ => bail!("unknown RoAd variant {variant}"),
        };
        if theta.len() != alpha.len() || theta.len() % per != 0 {
            bail!("bad theta/alpha lengths for variant {variant}");
        }
        let half = theta.len() / per;
        let d = half * 2;
        let mut r1 = vec![0f32; d];
        let mut r2 = vec![0f32; d];
        for k in 0..half {
            let (c1, s1, s2, c2) = match variant {
                1 => {
                    let (t, a) = (theta[k], alpha[k]);
                    (a * t.cos(), a * t.sin(), a * t.sin(), a * t.cos())
                }
                2 => {
                    let (t1, a1) = (theta[2 * k], alpha[2 * k]);
                    let (t2, a2) = (theta[2 * k + 1], alpha[2 * k + 1]);
                    (a1 * t1.cos(), a1 * t1.sin(), a2 * t2.sin(), a2 * t2.cos())
                }
                _ => {
                    let t = &theta[4 * k..4 * k + 4];
                    let a = &alpha[4 * k..4 * k + 4];
                    (a[0] * t[0].cos(), a[1] * t[1].sin(), a[2] * t[2].sin(), a[3] * t[3].cos())
                }
            };
            r1[2 * k] = c1;
            r1[2 * k + 1] = c2;
            r2[2 * k] = s1;
            r2[2 * k + 1] = s2;
        }
        Ok(RoadVectors { r1, r2 })
    }

    pub fn dim(&self) -> usize {
        self.r1.len()
    }
}

/// A trained RoAd adapter: effective vectors per adapted projection, keyed
/// `blocks.<i>.<proj>`.
#[derive(Clone, Debug, Default)]
pub struct RoadAdapter {
    pub per_proj: BTreeMap<String, RoadVectors>,
}

impl RoadAdapter {
    pub fn identity(cfg: &ModelConfigInfo) -> RoadAdapter {
        let mut per_proj = BTreeMap::new();
        for i in 0..cfg.n_layers {
            for proj in PROJS {
                let (_, d_out) = proj_dims(cfg, proj);
                per_proj.insert(format!("blocks.{i}.{proj}"), RoadVectors::identity(d_out));
            }
        }
        RoadAdapter { per_proj }
    }

    /// Random small rotations (used by serving benchmarks where only the
    /// *cost* of heterogeneous adapters matters, not trained quality).
    pub fn random(cfg: &ModelConfigInfo, rng: &mut Rng, scale: f32) -> RoadAdapter {
        let mut a = RoadAdapter::identity(cfg);
        for vecs in a.per_proj.values_mut() {
            let d = vecs.dim();
            let theta: Vec<f32> = (0..d / 2).map(|_| rng.normal() * scale).collect();
            let alpha: Vec<f32> = (0..d / 2).map(|_| 1.0 + rng.normal() * 0.02).collect();
            *vecs = RoadVectors::from_theta_alpha(1, &theta, &alpha).unwrap();
        }
        a
    }

    /// Build from a trainer's flat trainable tensors
    /// ("blocks.i.proj.theta"/".alpha").
    pub fn from_trainable(
        variant: usize,
        named: &[(String, HostTensor)],
    ) -> Result<RoadAdapter> {
        let mut per_proj = BTreeMap::new();
        let mut thetas: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        let mut alphas: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        for (name, t) in named {
            if let Some(base) = name.strip_suffix(".theta") {
                thetas.insert(base.to_string(), t.as_f32());
            } else if let Some(base) = name.strip_suffix(".alpha") {
                alphas.insert(base.to_string(), t.as_f32());
            }
        }
        for (base, th) in &thetas {
            let al = alphas
                .get(base)
                .ok_or_else(|| anyhow!("theta without alpha for {base}"))?;
            per_proj.insert(base.clone(), RoadVectors::from_theta_alpha(variant, th, al)?);
        }
        if per_proj.is_empty() {
            bail!("no road trainables found");
        }
        Ok(RoadAdapter { per_proj })
    }

    /// Subspace composition (paper §4.3 / Fig 5): take 2×2 blocks with index
    /// < split_blocks from `a`, the rest from `b`.  Disjoint blocks are
    /// orthogonal subspaces, so both tasks' rotations coexist in one R.
    pub fn compose(a: &RoadAdapter, b: &RoadAdapter, split_frac: f32) -> Result<RoadAdapter> {
        if !split_frac.is_finite() {
            bail!("split_frac must be finite, got {split_frac}");
        }
        let mut per_proj = BTreeMap::new();
        for (key, va) in &a.per_proj {
            let vb = b
                .per_proj
                .get(key)
                .ok_or_else(|| anyhow!("composition: {key} missing from second adapter"))?;
            let d = va.dim();
            if vb.dim() != d {
                bail!("composition dim mismatch at {key}");
            }
            let split = subspace_split(d, split_frac);
            let mut r1 = va.r1.clone();
            let mut r2 = va.r2.clone();
            r1[split..].copy_from_slice(&vb.r1[split..]);
            r2[split..].copy_from_slice(&vb.r2[split..]);
            per_proj.insert(key.clone(), RoadVectors { r1, r2 });
        }
        Ok(RoadAdapter { per_proj })
    }
}

/// Element index where the composed subspace boundary falls: `split_frac`
/// of the `d/2` rotation blocks (rounded to the nearest block, ties
/// down), times two elements per block.  Always even and within `[0, d]`.
///
/// Rounding happens once, in f64, on the *block count* — the earlier
/// `((d / 2) as f32 * split_frac) as usize` formulation both truncated
/// (0.7·10 blocks → 6, biased low by f32 representation) and lost integer
/// precision for d/2 beyond f32's 24-bit mantissa.  Ties round *down*
/// (`ceil(x - 0.5)`) so that `split_frac = 0.5` over an odd block count
/// lands on the same `n_blocks / 2` boundary as the trainer's half mask
/// ([`crate::compose::half_mask_sized`]) — composed halves take exactly
/// the blocks each task trained.
pub fn subspace_split(d: usize, split_frac: f32) -> usize {
    let half = d / 2;
    let x = split_frac.clamp(0.0, 1.0) as f64 * half as f64;
    let blocks = (x - 0.5).ceil().max(0.0) as usize;
    blocks.min(half) * 2
}

/// A trained LoRA adapter (the unmerged-serving baseline of Figure 4).
#[derive(Clone, Debug, Default)]
pub struct LoraAdapter {
    pub per_proj: BTreeMap<String, LoraMats>,
}

#[derive(Clone, Debug)]
pub struct LoraMats {
    pub lb: Vec<f32>, // [d_in, r]
    pub la: Vec<f32>, // [r, d_out]
    pub rank: usize,
    pub d_in: usize,
    pub d_out: usize,
}

impl LoraAdapter {
    pub fn zeros(cfg: &ModelConfigInfo) -> LoraAdapter {
        let mut per_proj = BTreeMap::new();
        for i in 0..cfg.n_layers {
            for proj in PROJS {
                let (d_in, d_out) = proj_dims(cfg, proj);
                per_proj.insert(
                    format!("blocks.{i}.{proj}"),
                    LoraMats {
                        lb: vec![0.0; d_in * cfg.lora_rank],
                        la: vec![0.0; cfg.lora_rank * d_out],
                        rank: cfg.lora_rank,
                        d_in,
                        d_out,
                    },
                );
            }
        }
        LoraAdapter { per_proj }
    }

    pub fn random(cfg: &ModelConfigInfo, rng: &mut Rng, scale: f32) -> LoraAdapter {
        let mut a = LoraAdapter::zeros(cfg);
        for m in a.per_proj.values_mut() {
            let s_in = scale / (m.d_in as f32).sqrt();
            m.lb = rng.normal_vec(m.d_in * m.rank, s_in);
            m.la = rng.normal_vec(m.rank * m.d_out, scale / (m.rank as f32).sqrt());
        }
        a
    }

    pub fn from_trainable(named: &[(String, HostTensor)]) -> Result<LoraAdapter> {
        let mut lbs: BTreeMap<String, HostTensor> = BTreeMap::new();
        let mut las: BTreeMap<String, HostTensor> = BTreeMap::new();
        for (name, t) in named {
            if let Some(base) = name.strip_suffix(".lb") {
                lbs.insert(base.to_string(), t.clone());
            } else if let Some(base) = name.strip_suffix(".la") {
                las.insert(base.to_string(), t.clone());
            }
        }
        let mut per_proj = BTreeMap::new();
        for (base, lb) in &lbs {
            let la = las.get(base).ok_or_else(|| anyhow!("lb without la at {base}"))?;
            per_proj.insert(
                base.clone(),
                LoraMats {
                    d_in: lb.shape[0],
                    rank: lb.shape[1],
                    d_out: la.shape[1],
                    lb: lb.as_f32(),
                    la: la.as_f32(),
                },
            );
        }
        if per_proj.is_empty() {
            bail!("no lora trainables found");
        }
        Ok(LoraAdapter { per_proj })
    }
}

/// (IA)³ scaling adapter.
#[derive(Clone, Debug, Default)]
pub struct Ia3Adapter {
    pub per_proj: BTreeMap<String, Vec<f32>>,
}

impl Ia3Adapter {
    pub fn identity(cfg: &ModelConfigInfo) -> Ia3Adapter {
        let mut per_proj = BTreeMap::new();
        for i in 0..cfg.n_layers {
            for proj in PROJS {
                let (_, d_out) = proj_dims(cfg, proj);
                per_proj.insert(format!("blocks.{i}.{proj}"), vec![1.0; d_out]);
            }
        }
        Ia3Adapter { per_proj }
    }

    /// Random scaling vectors centered on identity: `1 + N(0, scale)` per
    /// output channel, mirroring `RoadAdapter::random`'s near-identity init.
    pub fn random(cfg: &ModelConfigInfo, rng: &mut Rng, scale: f32) -> Ia3Adapter {
        let mut a = Ia3Adapter::identity(cfg);
        for s in a.per_proj.values_mut() {
            for v in s.iter_mut() {
                *v = 1.0 + rng.normal() * scale;
            }
        }
        a
    }
}

/// Any trained adapter.
#[derive(Clone, Debug)]
pub enum Adapter {
    Road(RoadAdapter),
    Lora(LoraAdapter),
    Ia3(Ia3Adapter),
}

impl Adapter {
    pub fn mode(&self) -> &'static str {
        match self {
            Adapter::Road(_) => "road",
            Adapter::Lora(_) => "lora",
            Adapter::Ia3(_) => "ia3",
        }
    }
}

/// Bank of adapter slots matching the HLO bank inputs: per bank key a
/// [n_slots, ...] tensor.  Slot 0 is reserved for identity so unoccupied
/// decode lanes are no-ops.
///
/// Dirty state is tracked *per slot*: installing one adapter marks only
/// that slot's rows stale, and [`AdapterBank::upload_dirty`] moves only
/// those rows host-to-device instead of re-shipping the whole bank.
pub struct AdapterBank {
    pub mode: String,
    pub n_slots: usize,
    /// bank key ("blocks.i.proj.r1" / ".lb" / ...) -> stacked tensor.
    pub tensors: BTreeMap<String, HostTensor>,
    /// Slots whose host rows are newer than the device copy.
    dirty_slots: BTreeSet<usize>,
    /// A fresh bank (or an explicit invalidation) re-uploads everything.
    all_dirty: bool,
}

/// What one [`AdapterBank::upload_dirty`] call actually transferred.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BankUpload {
    /// Host-to-device bytes attributable to bank content (full tensors on
    /// a whole-bank upload, only the touched slot rows on a paged upload).
    pub bytes: usize,
    /// Per-slot row tensors staged through the runtime on the paged path.
    pub staged_rows: usize,
    /// True when the whole bank was (re)uploaded.
    pub full: bool,
}

impl AdapterBank {
    pub fn new(cfg: &ModelConfigInfo, mode: &str, n_slots: usize) -> Result<AdapterBank> {
        let mut tensors = BTreeMap::new();
        for i in 0..cfg.n_layers {
            for proj in PROJS {
                let (d_in, d_out) = proj_dims(cfg, proj);
                let key = format!("blocks.{i}.{proj}");
                match mode {
                    "road" => {
                        if d_out % 2 != 0 {
                            bail!(
                                "config {}: road mode needs even projection widths, \
                                 {proj} has d_out {d_out} (the rotation pairs adjacent \
                                 elements and would silently skip the last one)",
                                cfg.name
                            );
                        }
                        let mut r1 = HostTensor::zeros(vec![n_slots, d_out], crate::tensor::DType::F32);
                        for s in 0..n_slots {
                            r1.write_f32_range(s * d_out, &vec![1.0; d_out]);
                        }
                        tensors.insert(format!("{key}.r1"), r1);
                        tensors.insert(
                            format!("{key}.r2"),
                            HostTensor::zeros(vec![n_slots, d_out], crate::tensor::DType::F32),
                        );
                    }
                    "lora" => {
                        tensors.insert(
                            format!("{key}.lb"),
                            HostTensor::zeros(
                                vec![n_slots, d_in, cfg.lora_rank],
                                crate::tensor::DType::F32,
                            ),
                        );
                        tensors.insert(
                            format!("{key}.la"),
                            HostTensor::zeros(
                                vec![n_slots, cfg.lora_rank, d_out],
                                crate::tensor::DType::F32,
                            ),
                        );
                    }
                    "ia3" => {
                        let mut s_t =
                            HostTensor::zeros(vec![n_slots, d_out], crate::tensor::DType::F32);
                        for s in 0..n_slots {
                            s_t.write_f32_range(s * d_out, &vec![1.0; d_out]);
                        }
                        tensors.insert(format!("{key}.s"), s_t);
                    }
                    "base" => {}
                    _ => bail!("unknown adapter mode {mode}"),
                }
            }
        }
        Ok(AdapterBank {
            mode: mode.to_string(),
            n_slots,
            tensors,
            dirty_slots: BTreeSet::new(),
            all_dirty: true,
        })
    }

    /// Any slot (or the whole bank) newer on host than on device?
    pub fn is_dirty(&self) -> bool {
        self.all_dirty || !self.dirty_slots.is_empty()
    }

    /// Slots with stale device rows (empty when `all_dirty` covers them).
    pub fn dirty_slots(&self) -> Vec<usize> {
        self.dirty_slots.iter().copied().collect()
    }

    /// Force the next upload to re-ship every tensor.
    pub fn mark_all_dirty(&mut self) {
        self.all_dirty = true;
    }

    /// Drop a slot's dirty mark without uploading (used when the slot is
    /// freed: its rows are unreferenced, so shipping them would be wasted
    /// traffic — re-occupation re-marks it via `set_slot`).
    pub fn clear_slot_dirty(&mut self, slot: usize) {
        self.dirty_slots.remove(&slot);
    }

    /// Host bytes of one slot's rows across every bank key.
    pub fn slot_bytes(&self) -> usize {
        self.tensors
            .values()
            .map(|t| t.bytes().len() / self.n_slots.max(1))
            .sum()
    }

    /// Host bytes of the full bank (every key, every slot).
    pub fn total_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.bytes().len()).sum()
    }

    /// Copy of one slot's row for `key`, shaped `[1, ...]` like a single
    /// page (the staging tensor for a per-slot upload).
    pub fn slot_row(&self, key: &str, slot: usize) -> Result<HostTensor> {
        let t = self.tensors.get(key).ok_or_else(|| anyhow!("bank missing {key}"))?;
        if slot >= self.n_slots {
            bail!("slot {slot} out of range ({})", self.n_slots);
        }
        let row_elems = t.elem_count() / self.n_slots;
        let mut shape = t.shape.clone();
        shape[0] = 1;
        Ok(HostTensor::f32(shape, t.read_f32_range(slot * row_elems, row_elems)))
    }

    /// Refresh the device copies in `bufs` from the host tensors, moving as
    /// little as the dirty state allows.  Returns `None` when nothing was
    /// stale (or the bank carries no tensors — base mode).
    ///
    /// * Whole-bank path (`paged = false`, a fresh bank, or an explicit
    ///   [`AdapterBank::mark_all_dirty`]): every stacked tensor is
    ///   re-uploaded; `bytes` counts the full bank.
    /// * Paged path: each dirty slot's rows are staged through real
    ///   per-row uploads and `bytes` counts only those rows — the
    ///   host-to-device traffic a paged bank actually pays.  On a native
    ///   PJRT backend the staged row would then be scattered into the
    ///   resident bank buffer by a compiled `dynamic-update-slice` step
    ///   (device-side, no further host traffic); the offline stub cannot
    ///   execute HLO, so the scatter is stood in for by refreshing the
    ///   stacked buffer from the already-current host mirror.
    pub fn upload_dirty(
        &mut self,
        client: &xla::PjRtClient,
        bufs: &mut BTreeMap<String, xla::PjRtBuffer>,
        paged: bool,
    ) -> Result<Option<BankUpload>> {
        if self.tensors.is_empty() {
            return Ok(None);
        }
        let fresh = bufs.len() != self.tensors.len();
        if !self.is_dirty() && !fresh {
            return Ok(None);
        }
        let mut up = BankUpload::default();
        if fresh || self.all_dirty || !paged {
            for (name, t) in &self.tensors {
                bufs.insert(name.clone(), crate::runtime::upload(client, t)?);
                up.bytes += t.bytes().len();
            }
            up.full = true;
        } else {
            for &slot in &self.dirty_slots {
                for key in self.tensors.keys() {
                    let row = self.slot_row(key, slot)?;
                    // The page transfer itself: one row host-to-device (on
                    // a native backend the staged buffer is consumed by
                    // the device-side scatter below).
                    let _staged = crate::runtime::upload(client, &row)?;
                    up.bytes += row.bytes().len();
                    up.staged_rows += 1;
                }
            }
            // Stand-in for the device-side scatter of the staged rows (see
            // doc comment): rebuild the stacked buffers from the host
            // mirror.  Not counted as bank traffic — on a real backend this
            // step never crosses the host/device boundary.
            for (name, t) in &self.tensors {
                bufs.insert(name.clone(), crate::runtime::upload(client, t)?);
            }
        }
        self.dirty_slots.clear();
        self.all_dirty = false;
        Ok(Some(up))
    }

    /// Install an adapter into bank slot `slot`.
    pub fn set_slot(&mut self, slot: usize, adapter: &Adapter) -> Result<()> {
        if slot >= self.n_slots {
            bail!("slot {slot} out of range ({})", self.n_slots);
        }
        match (adapter, self.mode.as_str()) {
            (Adapter::Road(a), "road") => {
                for (key, vecs) in &a.per_proj {
                    let d = vecs.dim();
                    self.tensors
                        .get_mut(&format!("{key}.r1"))
                        .ok_or_else(|| anyhow!("bank missing {key}.r1"))?
                        .write_f32_range(slot * d, &vecs.r1);
                    self.tensors
                        .get_mut(&format!("{key}.r2"))
                        .ok_or_else(|| anyhow!("bank missing {key}.r2"))?
                        .write_f32_range(slot * d, &vecs.r2);
                }
            }
            (Adapter::Lora(a), "lora") => {
                for (key, m) in &a.per_proj {
                    self.tensors
                        .get_mut(&format!("{key}.lb"))
                        .ok_or_else(|| anyhow!("bank missing {key}.lb"))?
                        .write_f32_range(slot * m.d_in * m.rank, &m.lb);
                    self.tensors
                        .get_mut(&format!("{key}.la"))
                        .ok_or_else(|| anyhow!("bank missing {key}.la"))?
                        .write_f32_range(slot * m.rank * m.d_out, &m.la);
                }
            }
            (Adapter::Ia3(a), "ia3") => {
                for (key, s) in &a.per_proj {
                    self.tensors
                        .get_mut(&format!("{key}.s"))
                        .ok_or_else(|| anyhow!("bank missing {key}.s"))?
                        .write_f32_range(slot * s.len(), s);
                }
            }
            (a, m) => bail!("adapter mode {} incompatible with bank mode {m}", a.mode()),
        }
        self.dirty_slots.insert(slot);
        Ok(())
    }
}

/// Host-side store of trained adapters, keyed by user-visible name.
///
/// Unbounded: registration never fails for capacity reasons — device
/// residency is a separate, paged concern ([`AdapterRegistry`]).
pub struct AdapterStore {
    mode: String,
    adapters: BTreeMap<String, Adapter>,
}

impl AdapterStore {
    pub fn new(mode: &str) -> AdapterStore {
        AdapterStore { mode: mode.to_string(), adapters: BTreeMap::new() }
    }

    /// Insert or replace `name`.  Only mode mismatches fail — there is no
    /// capacity limit.
    pub fn insert(&mut self, name: &str, adapter: &Adapter) -> Result<()> {
        if adapter.mode() != self.mode {
            bail!("adapter mode {} incompatible with store mode {}", adapter.mode(), self.mode);
        }
        self.adapters.insert(name.to_string(), adapter.clone());
        Ok(())
    }

    pub fn remove(&mut self, name: &str) -> Option<Adapter> {
        self.adapters.remove(name)
    }

    pub fn get(&self, name: &str) -> Option<&Adapter> {
        self.adapters.get(name)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.adapters.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.adapters.keys().map(|s| s.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.adapters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.adapters.is_empty()
    }
}

/// Result of paging an adapter toward device residency.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PageOutcome {
    /// Already resident in this bank slot (LRU stamp refreshed).
    Hit(usize),
    /// Paged into `slot`; `evicted` names the adapter that lost the slot.
    Paged { slot: usize, evicted: Option<String> },
    /// Every pageable slot is pinned by an in-flight request; the caller
    /// should leave the request queued and retry after a lane frees up.
    Stalled,
}

/// The serving-side registry: an unbounded [`AdapterStore`] fronted by the
/// device [`AdapterBank`] acting as an LRU page cache of bank slots.
///
/// Slot 0 is reserved for identity (requests without an adapter) and is
/// never paged or evicted.  `usable` may be smaller than the bank's tensor
/// slot count to model a tighter device budget than the compiled artifact
/// allows (the adapter-churn bench pins it to a few slots).
///
/// The residency/pin/LRU mechanics are the shared
/// [`LruPager`] — the same implementation that pages KV blocks in
/// [`crate::coordinator::pool::BlockPool`].
pub struct AdapterRegistry {
    pub bank: AdapterBank,
    pub store: AdapterStore,
    pager: LruPager<String>,
}

impl AdapterRegistry {
    pub fn new(bank: AdapterBank) -> AdapterRegistry {
        let usable = bank.n_slots;
        AdapterRegistry::with_usable_slots(bank, usable)
    }

    /// Like [`AdapterRegistry::new`], but only slots `1..usable` are
    /// pageable (clamped to the bank's real slot count).
    pub fn with_usable_slots(bank: AdapterBank, usable: usize) -> AdapterRegistry {
        let usable = usable.min(bank.n_slots);
        let store = AdapterStore::new(&bank.mode);
        AdapterRegistry { pager: LruPager::new(bank.n_slots, 1, usable), bank, store }
    }

    /// Register (or replace) a named adapter in the host store.  Always
    /// succeeds for new names — capacity is the store's, not the bank's.
    ///
    /// Replacing an adapter that is currently pinned by an in-flight
    /// request is rejected so active lanes keep the weights they started
    /// with; replacing a merely-resident adapter rewrites its slot in
    /// place.
    pub fn register(&mut self, name: &str, adapter: &Adapter) -> Result<()> {
        if adapter.mode() != self.bank.mode {
            bail!(
                "adapter mode {} incompatible with bank mode {}",
                adapter.mode(),
                self.bank.mode
            );
        }
        if let Some(slot) = self.pager.get(name) {
            if self.pager.is_pinned(slot) {
                bail!(
                    "adapter {name:?} is serving in-flight requests (bank slot {slot} is \
                     pinned); re-register after they finish"
                );
            }
            self.bank.set_slot(slot, adapter)?;
        }
        self.store.insert(name, adapter)
    }

    /// Remove `name` from the store (and its bank slot, when resident).
    /// Rejected while the adapter is pinned by an in-flight request.
    pub fn unregister(&mut self, name: &str) -> Result<()> {
        if !self.store.contains(name) {
            bail!("unknown adapter {name:?}");
        }
        if let Some(slot) = self.pager.get(name) {
            if self.pager.is_pinned(slot) {
                bail!(
                    "adapter {name:?} is serving in-flight requests (bank slot {slot} is \
                     pinned); unregister after they finish"
                );
            }
            self.pager.unbind(slot);
            self.bank.clear_slot_dirty(slot);
        }
        self.store.remove(name);
        Ok(())
    }

    /// Drop `name` from the device bank but keep it in the store.  Returns
    /// whether a slot was actually freed (false = registered but not
    /// resident); unknown names and pinned adapters are rejected.
    pub fn evict(&mut self, name: &str) -> Result<bool> {
        if !self.store.contains(name) {
            bail!("unknown adapter {name:?}");
        }
        let Some(slot) = self.pager.get(name) else {
            return Ok(false);
        };
        if self.pager.is_pinned(slot) {
            bail!("adapter {name:?} is pinned by an in-flight request; cannot evict");
        }
        self.pager.unbind(slot);
        self.bank.clear_slot_dirty(slot);
        Ok(true)
    }

    /// Make `name` device-resident, paging it into a free or LRU-evictable
    /// slot if needed.  [`PageOutcome::Stalled`] means every pageable slot
    /// is pinned — the caller defers admission rather than corrupting an
    /// active lane.
    pub fn ensure_resident(&mut self, name: &str) -> Result<PageOutcome> {
        if !self.store.contains(name) {
            bail!("unknown adapter {name:?}");
        }
        if let Some(slot) = self.pager.touch(name) {
            return Ok(PageOutcome::Hit(slot));
        }
        // Victim selection over pageable slots 1..usable: any free slot
        // first, else the least-recently-used unpinned slot.
        let Some(slot) = self.pager.free_slot().or_else(|| self.pager.evict_lru()) else {
            return Ok(PageOutcome::Stalled);
        };
        let evicted = self.pager.unbind(slot);
        let Some(adapter) = self.store.get(name) else {
            bail!("unknown adapter {name:?}");
        };
        self.bank.set_slot(slot, adapter)?;
        self.pager.bind(slot, name.to_string())?;
        Ok(PageOutcome::Paged { slot, evicted })
    }

    /// Pin `slot` for an in-flight request (no-op for the identity slot).
    pub fn pin(&mut self, slot: usize) {
        self.pager.pin(slot);
    }

    /// Release one pin on `slot` (no-op for the identity slot).
    pub fn unpin(&mut self, slot: usize) {
        self.pager.unpin(slot);
    }

    pub fn is_pinned(&self, slot: usize) -> bool {
        self.pager.is_pinned(slot)
    }

    /// Device slot of `name`, when resident.
    pub fn slot_of(&self, name: &str) -> Option<usize> {
        self.pager.get(name)
    }

    /// Names currently holding a device slot.
    pub fn resident_names(&self) -> Vec<&str> {
        self.pager.resident_keys().into_iter().map(|s| s.as_str()).collect()
    }

    /// All registered names (resident or not).
    pub fn names(&self) -> Vec<&str> {
        self.store.names()
    }

    /// Registered adapter count (the store's, not the bank's).
    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// Pageable device slots (slot 0 is reserved for identity).
    pub fn capacity(&self) -> usize {
        self.pager.pageable_len()
    }

    pub fn resident_len(&self) -> usize {
        self.pager.resident_len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfigInfo {
        ModelConfigInfo {
            name: "t".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
            d_ff: 12,
            max_seq: 16,
            head_dim: 4,
            n_adapters: 4,
            lora_rank: 2,
        }
    }

    #[test]
    fn road_bank_rejects_odd_projection_width() {
        // d_ff = 13 makes wgate/wup gather an odd d_out; the rotation pairs
        // adjacent elements, so construction must fail instead of serving a
        // bank that silently leaves the last channel unrotated.
        let mut cfg = tiny_cfg();
        cfg.d_ff = 13;
        let err = AdapterBank::new(&cfg, "road", 4).unwrap_err().to_string();
        assert!(err.contains("even projection widths"), "unexpected error: {err}");
        assert!(err.contains("d_out 13"), "unexpected error: {err}");
        // lora / ia3 don't pair elements and stay constructible.
        assert!(AdapterBank::new(&cfg, "lora", 4).is_ok());
        assert!(AdapterBank::new(&cfg, "ia3", 4).is_ok());
    }

    #[test]
    fn ia3_random_is_near_identity_and_deterministic() {
        let cfg = tiny_cfg();
        let mut rng = Rng::seed_from(7);
        let a = Ia3Adapter::random(&cfg, &mut rng, 0.05);
        let mut rng2 = Rng::seed_from(7);
        let b = Ia3Adapter::random(&cfg, &mut rng2, 0.05);
        assert_eq!(a.per_proj, b.per_proj);
        for s in a.per_proj.values() {
            for &v in s {
                assert!((v - 1.0).abs() < 1.0, "scale {v} too far from identity");
            }
        }
    }

    #[test]
    fn variant1_identity() {
        let v = RoadVectors::from_theta_alpha(1, &[0.0; 4], &[1.0; 4]).unwrap();
        assert_eq!(v.r1, vec![1.0; 8]);
        assert_eq!(v.r2, vec![0.0; 8]);
    }

    #[test]
    fn variant2_matches_variant1_when_shared(){
        let theta = [0.3f32, -0.2];
        let alpha = [1.1f32, 0.9];
        let v1 = RoadVectors::from_theta_alpha(1, &theta, &alpha).unwrap();
        let t2 = [0.3f32, 0.3, -0.2, -0.2];
        let a2 = [1.1f32, 1.1, 0.9, 0.9];
        let v2 = RoadVectors::from_theta_alpha(2, &t2, &a2).unwrap();
        for i in 0..4 {
            assert!((v1.r1[i] - v2.r1[i]).abs() < 1e-6);
            assert!((v1.r2[i] - v2.r2[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn compose_takes_halves() {
        let cfg = tiny_cfg();
        let mut rng = Rng::seed_from(0);
        let a = RoadAdapter::random(&cfg, &mut rng, 0.3);
        let b = RoadAdapter::random(&cfg, &mut rng, 0.3);
        let c = RoadAdapter::compose(&a, &b, 0.5).unwrap();
        for (key, vc) in &c.per_proj {
            let va = &a.per_proj[key];
            let vb = &b.per_proj[key];
            let d = vc.dim();
            assert_eq!(&vc.r1[..d / 2], &va.r1[..d / 2]);
            assert_eq!(&vc.r1[d / 2..], &vb.r1[d / 2..]);
            assert_eq!(&vc.r2[..d / 2], &va.r2[..d / 2]);
            assert_eq!(&vc.r2[d / 2..], &vb.r2[d / 2..]);
        }
    }

    #[test]
    fn subspace_split_edges() {
        // 0.0 → everything from b; 1.0 → everything from a.
        assert_eq!(subspace_split(8, 0.0), 0);
        assert_eq!(subspace_split(8, 1.0), 8);
        // Out-of-range fractions clamp instead of over/underflowing.
        assert_eq!(subspace_split(8, -0.5), 0);
        assert_eq!(subspace_split(8, 1.5), 8);
        // Odd block counts: nearest block, ties down — 0.5 must land on the
        // trainer's `n_blocks / 2` mask boundary so composed halves take
        // exactly the blocks each task trained.
        assert_eq!(subspace_split(6, 0.5), 2); // 3 blocks · 0.5 = 1.5 → 1 block
        assert_eq!(subspace_split(10, 0.5), 4); // 5 blocks · 0.5 = 2.5 → 2 blocks
        for d in [6usize, 10, 14, 22] {
            assert_eq!(subspace_split(d, 0.5), (d / 2 / 2) * 2, "mask alignment at d={d}");
        }
        // Non-tie fractions round to nearest (the old f32 formulation
        // truncated: 0.7 · 10 blocks gave 6).
        assert_eq!(subspace_split(20, 0.7), 14);
        assert_eq!(subspace_split(10, 0.49), 4);
        // Large d: 2^25 + 2 elements has d/2 beyond f32's mantissa; the f32
        // formulation misplaced the boundary, the f64 one does not.
        let d = (1usize << 25) + 2;
        let half = d / 2;
        assert_eq!(subspace_split(d, 1.0), d);
        assert_eq!(subspace_split(d, 0.25), (half / 4) * 2);
        // Every result is even and bounded by d.
        for frac in [0.0f32, 0.1, 0.3333, 0.5, 0.9999, 1.0] {
            let s = subspace_split(14, frac);
            assert_eq!(s % 2, 0);
            assert!(s <= 14);
        }
    }

    #[test]
    fn compose_edge_fractions_take_whole_adapter() {
        let cfg = tiny_cfg();
        let mut rng = Rng::seed_from(11);
        let a = RoadAdapter::random(&cfg, &mut rng, 0.3);
        let b = RoadAdapter::random(&cfg, &mut rng, 0.3);
        let all_b = RoadAdapter::compose(&a, &b, 0.0).unwrap();
        let all_a = RoadAdapter::compose(&a, &b, 1.0).unwrap();
        for key in a.per_proj.keys() {
            assert_eq!(all_b.per_proj[key], b.per_proj[key]);
            assert_eq!(all_a.per_proj[key], a.per_proj[key]);
        }
        assert!(RoadAdapter::compose(&a, &b, f32::NAN).is_err());
    }

    #[test]
    fn bank_slot0_identity_and_set() {
        let cfg = tiny_cfg();
        let mut bank = AdapterBank::new(&cfg, "road", 4).unwrap();
        let r1 = bank.tensors.get("blocks.0.wq.r1").unwrap();
        assert_eq!(r1.read_f32_range(0, 8), vec![1.0; 8]);
        let mut rng = Rng::seed_from(1);
        let a = Adapter::Road(RoadAdapter::random(&cfg, &mut rng, 0.3));
        bank.set_slot(2, &a).unwrap();
        let r1 = bank.tensors.get("blocks.0.wq.r1").unwrap();
        // slot 0 untouched, slot 2 changed
        assert_eq!(r1.read_f32_range(0, 8), vec![1.0; 8]);
        assert_ne!(r1.read_f32_range(16, 8), vec![1.0; 8]);
    }

    fn road_reg(n_slots: usize) -> (AdapterRegistry, Rng) {
        let cfg = tiny_cfg();
        let bank = AdapterBank::new(&cfg, "road", n_slots).unwrap();
        (AdapterRegistry::new(bank), Rng::seed_from(2))
    }

    fn rand_adapter(rng: &mut Rng) -> Adapter {
        Adapter::Road(RoadAdapter::random(&tiny_cfg(), rng, 0.3))
    }

    #[test]
    fn registration_always_succeeds_beyond_bank_capacity() {
        let (mut reg, mut rng) = road_reg(4);
        for i in 0..50 {
            let a = rand_adapter(&mut rng);
            reg.register(&format!("user-{i}"), &a).unwrap();
        }
        assert_eq!(reg.len(), 50);
        assert_eq!(reg.capacity(), 3);
        assert_eq!(reg.resident_len(), 0, "registration does not page in");
        // Paging makes them resident on demand, never more than capacity.
        for i in 0..50 {
            let out = reg.ensure_resident(&format!("user-{i}")).unwrap();
            assert!(matches!(out, PageOutcome::Paged { .. } | PageOutcome::Hit(_)));
            assert!(reg.resident_len() <= reg.capacity());
        }
    }

    #[test]
    fn lru_eviction_order() {
        let (mut reg, mut rng) = road_reg(3); // 2 pageable slots
        for name in ["a", "b", "c"] {
            reg.register(name, &rand_adapter(&mut rng)).unwrap();
        }
        let sa = match reg.ensure_resident("a").unwrap() {
            PageOutcome::Paged { slot, evicted: None } => slot,
            o => panic!("expected clean page-in, got {o:?}"),
        };
        let _sb = match reg.ensure_resident("b").unwrap() {
            PageOutcome::Paged { slot, evicted: None } => slot,
            o => panic!("expected clean page-in, got {o:?}"),
        };
        // Touch "a" so "b" becomes least recently used.
        assert_eq!(reg.ensure_resident("a").unwrap(), PageOutcome::Hit(sa));
        match reg.ensure_resident("c").unwrap() {
            PageOutcome::Paged { evicted: Some(victim), .. } => assert_eq!(victim, "b"),
            o => panic!("expected eviction of b, got {o:?}"),
        }
        assert_eq!(reg.slot_of("b"), None);
        assert!(reg.store.contains("b"), "eviction keeps the store copy");
        // Paging "b" back now evicts "a" (older stamp than "c").
        match reg.ensure_resident("b").unwrap() {
            PageOutcome::Paged { evicted: Some(victim), .. } => assert_eq!(victim, "a"),
            o => panic!("expected eviction of a, got {o:?}"),
        }
    }

    #[test]
    fn pinned_slots_are_never_evicted() {
        let (mut reg, mut rng) = road_reg(3);
        for name in ["a", "b", "c"] {
            reg.register(name, &rand_adapter(&mut rng)).unwrap();
        }
        let sa = match reg.ensure_resident("a").unwrap() {
            PageOutcome::Paged { slot, .. } => slot,
            o => panic!("{o:?}"),
        };
        let sb = match reg.ensure_resident("b").unwrap() {
            PageOutcome::Paged { slot, .. } => slot,
            o => panic!("{o:?}"),
        };
        reg.pin(sa);
        reg.pin(sb);
        // Both pageable slots pinned: paging "c" must stall, not evict.
        assert_eq!(reg.ensure_resident("c").unwrap(), PageOutcome::Stalled);
        reg.unpin(sb);
        match reg.ensure_resident("c").unwrap() {
            PageOutcome::Paged { slot, evicted: Some(victim) } => {
                assert_eq!(slot, sb);
                assert_eq!(victim, "b", "only the unpinned slot is a victim");
            }
            o => panic!("{o:?}"),
        }
        assert_eq!(reg.slot_of("a"), Some(sa), "pinned adapter kept its slot");
    }

    #[test]
    fn unregister_of_in_flight_adapter_is_rejected() {
        let (mut reg, mut rng) = road_reg(4);
        reg.register("busy", &rand_adapter(&mut rng)).unwrap();
        let slot = match reg.ensure_resident("busy").unwrap() {
            PageOutcome::Paged { slot, .. } => slot,
            o => panic!("{o:?}"),
        };
        reg.pin(slot);
        assert!(reg.unregister("busy").is_err(), "pinned adapter must not unregister");
        assert!(reg.evict("busy").is_err(), "pinned adapter must not evict");
        let replacement = rand_adapter(&mut rng);
        assert!(reg.register("busy", &replacement).is_err(), "pinned adapter must not be replaced");
        reg.unpin(slot);
        reg.unregister("busy").unwrap();
        assert!(!reg.store.contains("busy"));
        assert_eq!(reg.slot_of("busy"), None);
        assert!(reg.unregister("busy").is_err(), "double unregister is unknown");
    }

    #[test]
    fn evict_clears_dirty_mark_of_freed_slot() {
        let (mut reg, mut rng) = road_reg(4);
        reg.register("a", &rand_adapter(&mut rng)).unwrap();
        let slot = match reg.ensure_resident("a").unwrap() {
            PageOutcome::Paged { slot, .. } => slot,
            o => panic!("{o:?}"),
        };
        assert_eq!(reg.bank.dirty_slots(), vec![slot]);
        assert!(reg.evict("a").unwrap());
        assert!(
            reg.bank.dirty_slots().is_empty(),
            "freed slot must not be staged on the next upload"
        );
        // Same through unregister.
        reg.register("b", &rand_adapter(&mut rng)).unwrap();
        reg.ensure_resident("b").unwrap();
        assert!(!reg.bank.dirty_slots().is_empty());
        reg.unregister("b").unwrap();
        assert!(reg.bank.dirty_slots().is_empty());
    }

    #[test]
    fn reregister_resident_rewrites_slot_in_place() {
        let (mut reg, mut rng) = road_reg(4);
        reg.register("u", &rand_adapter(&mut rng)).unwrap();
        let slot = match reg.ensure_resident("u").unwrap() {
            PageOutcome::Paged { slot, .. } => slot,
            o => panic!("{o:?}"),
        };
        let before = reg.bank.tensors["blocks.0.wq.r1"].read_f32_range(slot * 8, 8);
        reg.register("u", &rand_adapter(&mut rng)).unwrap();
        assert_eq!(reg.slot_of("u"), Some(slot), "still resident in the same slot");
        let after = reg.bank.tensors["blocks.0.wq.r1"].read_f32_range(slot * 8, 8);
        assert_ne!(before, after, "slot rows updated with the new weights");
    }

    #[test]
    fn per_slot_dirty_tracking_and_paged_upload() {
        let cfg = tiny_cfg();
        let client = xla::PjRtClient::cpu().unwrap();
        let mut bank = AdapterBank::new(&cfg, "road", 4).unwrap();
        let mut bufs = std::collections::BTreeMap::new();
        // Fresh bank: full upload of every tensor.
        let up = bank.upload_dirty(&client, &mut bufs, true).unwrap().unwrap();
        assert!(up.full);
        assert_eq!(up.bytes, bank.total_bytes());
        assert_eq!(bufs.len(), bank.tensors.len());
        // Clean bank: nothing moves.
        assert!(bank.upload_dirty(&client, &mut bufs, true).unwrap().is_none());

        // One slot changes: the paged path moves only that slot's rows.
        let mut rng = Rng::seed_from(3);
        let a = rand_adapter(&mut rng);
        bank.set_slot(2, &a).unwrap();
        assert_eq!(bank.dirty_slots(), vec![2]);
        let up = bank.upload_dirty(&client, &mut bufs, true).unwrap().unwrap();
        assert!(!up.full);
        assert_eq!(up.staged_rows, bank.tensors.len(), "one row staged per bank key");
        assert_eq!(up.bytes, bank.slot_bytes());
        assert!(up.bytes * 4 == bank.total_bytes(), "4-slot bank: one slot is a quarter");

        // The whole-bank baseline re-ships everything for the same change.
        bank.set_slot(2, &a).unwrap();
        let up = bank.upload_dirty(&client, &mut bufs, false).unwrap().unwrap();
        assert!(up.full);
        assert_eq!(up.bytes, bank.total_bytes());
        assert!(!bank.is_dirty());
    }

    /// Eq. 2 with alpha = 1 is a pure rotation: every 2-element block of
    /// the rotated vector keeps its Euclidean norm exactly (the property
    /// the paper's "angle-only adaptation" pilot rests on).  Variants 2/4
    /// reduce to variant 1 when their cells share (theta, alpha), so the
    /// preservation carries over.
    #[test]
    fn from_theta_alpha_preserves_block_norms_when_alpha_is_one() {
        let mut rng = Rng::seed_from(13);
        let d = 16usize;
        let theta: Vec<f32> = (0..d / 2).map(|_| rng.normal() * 2.0).collect();
        let v = RoadVectors::from_theta_alpha(1, &theta, &vec![1.0; d / 2]).unwrap();
        let h: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let z = crate::model::road_rotate_vec(&h, &v.r1, &v.r2);
        for k in 0..d / 2 {
            let (e, o) = (2 * k, 2 * k + 1);
            let nh = (h[e] * h[e] + h[o] * h[o]).sqrt();
            let nz = (z[e] * z[e] + z[o] * z[o]).sqrt();
            assert!((nh - nz).abs() < 1e-5, "block {k}: |h|={nh} vs |Rh|={nz}");
        }
        // alpha != 1 scales the block norm by alpha (variant 1 shares one
        // alpha per block): the magnitude/angle decomposition of Eq. 3.
        let va = RoadVectors::from_theta_alpha(1, &theta, &vec![2.0; d / 2]).unwrap();
        let za = crate::model::road_rotate_vec(&h, &va.r1, &va.r2);
        for k in 0..d / 2 {
            let (e, o) = (2 * k, 2 * k + 1);
            let nh = (h[e] * h[e] + h[o] * h[o]).sqrt();
            let nz = (za[e] * za[e] + za[o] * za[o]).sqrt();
            assert!((2.0 * nh - nz).abs() < 1e-4, "block {k}: 2|h|={} vs {nz}", 2.0 * nh);
        }
    }

    #[test]
    fn variant4_matches_variant1_when_cells_shared() {
        let theta = [0.4f32, -0.7];
        let alpha = [1.2f32, 0.8];
        let v1 = RoadVectors::from_theta_alpha(1, &theta, &alpha).unwrap();
        let mut t4 = Vec::new();
        let mut a4 = Vec::new();
        for k in 0..2 {
            t4.extend_from_slice(&[theta[k]; 4]);
            a4.extend_from_slice(&[alpha[k]; 4]);
        }
        let v4 = RoadVectors::from_theta_alpha(4, &t4, &a4).unwrap();
        for i in 0..4 {
            assert!((v1.r1[i] - v4.r1[i]).abs() < 1e-6);
            assert!((v1.r2[i] - v4.r2[i]).abs() < 1e-6);
        }
        // Length/variant mismatches are rejected, not mis-read.
        assert!(RoadVectors::from_theta_alpha(4, &t4[..7], &a4[..7]).is_err());
        assert!(RoadVectors::from_theta_alpha(3, &theta, &alpha).is_err());
        assert!(RoadVectors::from_theta_alpha(2, &theta, &alpha[..1]).is_err());
    }

    /// Block-count edge cases of the composition boundary: a single-block
    /// projection (d = 2) has only "all of a" or "all of b"; tiny
    /// fractions round to the nearest block rather than truncating.
    #[test]
    fn subspace_split_single_block_and_rounding_edges() {
        // d = 2: one block. Ties round down, so 0.5 lands on 0 (all b);
        // anything past half a block rounds up to the whole block.
        assert_eq!(subspace_split(2, 0.0), 0);
        assert_eq!(subspace_split(2, 0.5), 0);
        assert_eq!(subspace_split(2, 0.51), 2);
        assert_eq!(subspace_split(2, 1.0), 2);
        // d = 4: two blocks; 0.25 is the tie at half a block.
        assert_eq!(subspace_split(4, 0.25), 0);
        assert_eq!(subspace_split(4, 0.26), 2);
        assert_eq!(subspace_split(4, 0.75), 2);
        assert_eq!(subspace_split(4, 0.76), 4);
        // Degenerate d = 0 never panics.
        assert_eq!(subspace_split(0, 0.5), 0);
        // Non-finite fractions are rejected by compose, and the split
        // helper clamps infinities instead of overflowing.
        assert_eq!(subspace_split(8, f32::INFINITY), 8);
        assert_eq!(subspace_split(8, f32::NEG_INFINITY), 0);
    }

    #[test]
    fn compose_rejects_mismatched_adapters_and_nan() {
        let cfg = tiny_cfg();
        let mut rng = Rng::seed_from(17);
        let a = RoadAdapter::random(&cfg, &mut rng, 0.3);
        let b = RoadAdapter::random(&cfg, &mut rng, 0.3);
        assert!(RoadAdapter::compose(&a, &b, f32::NAN).is_err());
        // A second adapter missing a projection is rejected.
        let mut partial = b.clone();
        partial.per_proj.remove("blocks.0.wq");
        assert!(RoadAdapter::compose(&a, &partial, 0.5).is_err());
        // Dimension mismatches are rejected.
        let mut wrong = b.clone();
        wrong.per_proj.insert("blocks.0.wq".into(), RoadVectors::identity(4));
        assert!(RoadAdapter::compose(&a, &wrong, 0.5).is_err());
    }

    /// The identity adapter is a numeric no-op through the *reference
    /// forward pass*: installing `RoadAdapter::identity` into a bank slot
    /// and decoding with it yields the base entry's logits (full
    /// embedding → attention → MLP stack, not just the epilogue math).
    #[test]
    fn identity_adapter_is_noop_through_reference_forward() {
        let rt = crate::runtime::Runtime::reference();
        let cfg = rt.manifest.config("tiny").unwrap().clone();
        let store = crate::model::ParamStore::load_pretrained(&rt.manifest, "tiny").unwrap();
        // Bank with the identity adapter installed in slot 1 (slot 0 is
        // the reserved identity page — exercising set_slot is the point).
        let mut bank = AdapterBank::new(&cfg, "road", cfg.n_adapters).unwrap();
        bank.set_slot(1, &Adapter::Road(RoadAdapter::identity(&cfg))).unwrap();

        let cache = vec![cfg.n_layers, 2, cfg.n_heads, cfg.max_seq, cfg.head_dim];
        let n: usize = cache.iter().product();
        let mut rng = Rng::seed_from(23);
        let data: BTreeMap<&str, HostTensor> = BTreeMap::from([
            ("ids", HostTensor::i32(vec![2], vec![1, 1])),
            ("token", HostTensor::i32(vec![2], vec![9, 77])),
            ("pos", HostTensor::i32(vec![2], vec![3, 5])),
            ("k_cache", HostTensor::f32(cache.clone(), rng.normal_vec(n, 0.02))),
            ("v_cache", HostTensor::f32(cache, rng.normal_vec(n, 0.02))),
        ]);
        let gather = |entry: &str, bank: Option<&AdapterBank>| -> Vec<HostTensor> {
            rt.manifest
                .entry(entry)
                .unwrap()
                .inputs
                .iter()
                .map(|s| match s.group.as_str() {
                    "params" => store.get(&s.name).unwrap().clone(),
                    "adapters" => bank.unwrap().tensors[&s.name].clone(),
                    _ => data[s.name.as_str()].clone(),
                })
                .collect()
        };
        let road_ins = gather("decode_road_tiny_b2", Some(&bank));
        let base_ins = gather("decode_base_tiny_b2", None);
        let road_refs: Vec<&HostTensor> = road_ins.iter().collect();
        let base_refs: Vec<&HostTensor> = base_ins.iter().collect();
        let road_out =
            rt.load("decode_road_tiny_b2").unwrap().run_host(&road_refs).unwrap();
        let base_out =
            rt.load("decode_base_tiny_b2").unwrap().run_host(&base_refs).unwrap();
        crate::runtime::allclose(&road_out[0], &base_out[0], 0.0, 1e-6)
            .expect("identity adapter changed the forward pass");
    }

    #[test]
    fn mode_mismatch_rejected() {
        let cfg = tiny_cfg();
        let mut bank = AdapterBank::new(&cfg, "road", 2).unwrap();
        let l = Adapter::Lora(LoraAdapter::zeros(&cfg));
        assert!(bank.set_slot(1, &l).is_err());
        let mut reg = AdapterRegistry::new(AdapterBank::new(&cfg, "road", 2).unwrap());
        assert!(reg.register("l", &Adapter::Lora(LoraAdapter::zeros(&cfg))).is_err());
        assert!(!reg.store.contains("l"), "rejected registration leaves no store entry");
    }
}
