//! Virtual-time abstraction for the serving coordinator.
//!
//! Every timestamp the engine takes — submit stamps, TTFT/queue-wait
//! metrics, deadline enforcement, bench arrival processes — goes through a
//! [`Clock`] instead of calling `Instant::now()` directly.  Production
//! uses [`Clock::wall`]; tests and the deterministic scheduler study use
//! [`Clock::manual`], where time only moves when the driver advances it,
//! making the engine's entire temporal surface replayable tick-by-tick
//! with no sleeps.
//!
//! A manual clock still hands out real [`Instant`] values (a fixed base
//! plus the virtual offset), so everything downstream — `Duration`
//! arithmetic, `Request::expired`, latency recorders — works unchanged on
//! either clock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A time source: the real monotonic clock, or a manually advanced
/// virtual clock shared by everyone holding a clone.
#[derive(Clone, Debug, Default)]
pub enum Clock {
    /// `Instant::now()` — time advances by itself.
    #[default]
    Wall,
    /// Virtual time: a fixed base instant plus an offset that only moves
    /// via [`Clock::advance`]/[`Clock::sleep_until`].  Clones share the
    /// same offset, so an engine and its test driver see one timeline.
    Manual(Arc<ManualTime>),
}

/// Shared state of a manual clock (see [`Clock::Manual`]).
#[derive(Debug)]
pub struct ManualTime {
    base: Instant,
    nanos: AtomicU64,
}

impl Clock {
    pub fn wall() -> Clock {
        Clock::Wall
    }

    /// A fresh virtual clock starting at its own time zero.
    pub fn manual() -> Clock {
        Clock::Manual(Arc::new(ManualTime { base: Instant::now(), nanos: AtomicU64::new(0) }))
    }

    pub fn is_manual(&self) -> bool {
        matches!(self, Clock::Manual(_))
    }

    /// The current instant on this clock.
    pub fn now(&self) -> Instant {
        match self {
            Clock::Wall => Instant::now(),
            Clock::Manual(m) => m.base + Duration::from_nanos(m.nanos.load(Ordering::SeqCst)),
        }
    }

    /// Move a manual clock forward by `d`.  No-op on the wall clock,
    /// which advances by itself.
    pub fn advance(&self, d: Duration) {
        if let Clock::Manual(m) = self {
            m.nanos.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
        }
    }

    /// Block until `deadline`: the wall clock sleeps the thread; the
    /// manual clock jumps straight there (monotone — it never moves
    /// backward, so a deadline already in the past is a no-op).  This is
    /// how bench arrival processes wait without `thread::sleep` in their
    /// own code: on the manual clock the whole open loop runs instantly.
    pub fn sleep_until(&self, deadline: Instant) {
        match self {
            Clock::Wall => {
                let now = Instant::now();
                if deadline > now {
                    std::thread::sleep(deadline - now);
                }
            }
            Clock::Manual(m) => {
                let target = deadline.saturating_duration_since(m.base).as_nanos() as u64;
                m.nanos.fetch_max(target, Ordering::SeqCst);
            }
        }
    }

    /// [`Clock::sleep_until`] `d` from now.
    pub fn sleep(&self, d: Duration) {
        self.sleep_until(self.now() + d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_only_moves_when_advanced() {
        let c = Clock::manual();
        let t0 = c.now();
        assert_eq!(c.now(), t0, "virtual time stands still");
        c.advance(Duration::from_millis(250));
        assert_eq!(c.now() - t0, Duration::from_millis(250));
        c.advance(Duration::from_micros(1));
        assert_eq!(c.now() - t0, Duration::from_micros(250_001));
    }

    #[test]
    fn clones_share_one_timeline() {
        let a = Clock::manual();
        let b = a.clone();
        let t0 = a.now();
        b.advance(Duration::from_secs(2));
        assert_eq!(a.now() - t0, Duration::from_secs(2), "advance via any clone is visible");
        assert!(a.is_manual() && b.is_manual());
    }

    #[test]
    fn manual_sleep_jumps_and_never_rewinds() {
        let c = Clock::manual();
        let t0 = c.now();
        c.sleep_until(t0 + Duration::from_millis(10));
        assert_eq!(c.now() - t0, Duration::from_millis(10));
        // A deadline in the past does not move time backward.
        c.sleep_until(t0 + Duration::from_millis(5));
        assert_eq!(c.now() - t0, Duration::from_millis(10));
        c.sleep(Duration::from_millis(7));
        assert_eq!(c.now() - t0, Duration::from_millis(17));
    }

    #[test]
    fn wall_clock_advances_by_itself() {
        let c = Clock::wall();
        assert!(!c.is_manual());
        let t0 = c.now();
        c.advance(Duration::from_secs(3600)); // no-op on the wall clock
        // Sanity only: wall time moved forward by (far) less than the no-op
        // advance would have.
        assert!(c.now() >= t0);
        assert!(c.now() - t0 < Duration::from_secs(3600));
    }
}
