//! **bounded-channels** — unbounded fan-out on the connection plane is
//! an invariant violation, not a default.
//!
//! A slow reader on an unbounded channel buffers tokens without limit;
//! at 10k+ concurrent streams that is the memory ceiling (ROADMAP item
//! 4).  Constructing `mpsc::channel()` in `coordinator/net.rs` or
//! `coordinator/server.rs` therefore requires either a bounded
//! `sync_channel` (rendezvous handshakes carry exactly one message —
//! capacity 1 is free) or a justified
//! `// roadlint: allow(bounded-channels)` escape naming the teardown
//! path that bounds the buffer in practice.

use super::{code_matches, Finding, RepoContext};

pub const NAME: &str = "bounded-channels";

const FILES: [&str; 2] = ["rust/src/coordinator/net.rs", "rust/src/coordinator/server.rs"];

pub fn check(ctx: &RepoContext) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in &ctx.files {
        if !FILES.contains(&file.rel.as_str()) {
            continue;
        }
        for (i, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            // `code_matches` is identifier-boundary-aware, so
            // `sync_channel()` never matches the `channel()` needle.
            if !code_matches(&line.code, "channel()").is_empty()
                || !code_matches(&line.code, "channel::<").is_empty()
            {
                out.push(Finding {
                    rule: NAME,
                    path: file.rel.clone(),
                    line: i + 1,
                    message: "unbounded mpsc::channel() on the connection plane — use \
                              sync_channel (capacity 1 for rendezvous) or justify the \
                              escape with the path that bounds the buffer"
                        .into(),
                });
            }
        }
    }
    out
}
