pub fn pace() {
    std::thread::sleep(std::time::Duration::from_millis(2));
}
