//! Hand-rolled property tests (the offline image carries no proptest
//! crate): randomized invariants over the coordinator's state machines and
//! the RoAd math, each run across many seeded cases.
//!
//! The scheduler properties (`prop_sched_*`) honor `ROAD_PROPTEST_SEED`
//! so CI pins them to a fixed seed; a failure there reproduces
//! byte-for-byte with the same value.

use std::time::Duration;

use road::adapters::{Adapter, AdapterBank, AdapterRegistry, PageOutcome, RoadAdapter, RoadVectors};
use road::coordinator::kv::SlotAllocator;
use road::coordinator::pool::BlockPool;
use road::coordinator::queue::{AdmissionQueue, EngineError};
use road::coordinator::request::Request;
use road::coordinator::router::{FleetSim, FleetSimConfig, PlaceKind, Placer, ReplicaView};
use road::coordinator::sampler;
use road::coordinator::sched::{PolicyKind, SchedSim, SimOutcome};
use road::manifest::ModelConfigInfo;
use road::model::{road_merge_weight, road_rotate_vec};
use road::runtime::epilogue::{self, BankView};
use road::tasks::{lm_batch, Example};
use road::tensor::HostTensor;
use road::trainer::linear_lr;
use road::util::rng::Rng;

const CASES: usize = 200;

/// Seed for the scheduler property tests: `ROAD_PROPTEST_SEED` when set
/// (CI pins it), a fixed default otherwise — never wall-clock-derived.
fn prop_seed() -> u64 {
    std::env::var("ROAD_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x0A0D_5EED)
}

fn tiny_cfg() -> ModelConfigInfo {
    ModelConfigInfo {
        name: "t".into(),
        vocab: 16,
        d_model: 8,
        n_layers: 2,
        n_heads: 2,
        d_ff: 12,
        max_seq: 16,
        head_dim: 4,
        n_adapters: 6,
        lora_rank: 2,
    }
}

// ---------------------------------------------------------------------------
// RoAd math
// ---------------------------------------------------------------------------

#[test]
fn prop_pure_rotation_preserves_norm() {
    // alpha = 1 (Eq. 2): R is orthogonal, so ||R h|| == ||h||.
    let mut rng = Rng::seed_from(100);
    for _ in 0..CASES {
        let half = 1 + rng.below(16);
        let d = 2 * half;
        let theta: Vec<f32> = (0..half).map(|_| rng.normal() * 2.0).collect();
        let alpha = vec![1.0f32; half];
        let v = RoadVectors::from_theta_alpha(1, &theta, &alpha).unwrap();
        let h: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let z = road_rotate_vec(&h, &v.r1, &v.r2);
        let n0: f32 = h.iter().map(|x| x * x).sum::<f32>().sqrt();
        let n1: f32 = z.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((n0 - n1).abs() < 1e-4 * n0.max(1.0), "{n0} vs {n1}");
    }
}

#[test]
fn prop_alpha_scales_block_norm() {
    // With shared alpha per block, each 2D block's norm scales by |alpha|.
    let mut rng = Rng::seed_from(101);
    for _ in 0..CASES {
        let theta = [rng.normal()];
        let alpha = [0.25f32 + rng.f32() * 2.0];
        let v = RoadVectors::from_theta_alpha(1, &theta, &alpha).unwrap();
        let h = [rng.normal(), rng.normal()];
        let z = road_rotate_vec(&h, &v.r1, &v.r2);
        let n0 = (h[0] * h[0] + h[1] * h[1]).sqrt();
        let n1 = (z[0] * z[0] + z[1] * z[1]).sqrt();
        assert!((n1 - alpha[0] * n0).abs() < 1e-4 * n0.max(1.0));
    }
}

#[test]
fn prop_variants_nest() {
    // Variant 2 with duplicated params == variant 1; variant 4 with
    // duplicated row pairs == variant 2 (Table 1's sharing hierarchy).
    let mut rng = Rng::seed_from(102);
    for _ in 0..CASES {
        let half = 1 + rng.below(8);
        let t1: Vec<f32> = (0..half).map(|_| rng.normal()).collect();
        let a1: Vec<f32> = (0..half).map(|_| 1.0 + 0.1 * rng.normal()).collect();
        let v1 = RoadVectors::from_theta_alpha(1, &t1, &a1).unwrap();

        let t2: Vec<f32> = t1.iter().flat_map(|&t| [t, t]).collect();
        let a2: Vec<f32> = a1.iter().flat_map(|&a| [a, a]).collect();
        let v2 = RoadVectors::from_theta_alpha(2, &t2, &a2).unwrap();

        let t4: Vec<f32> = t1.iter().flat_map(|&t| [t, t, t, t]).collect();
        let a4: Vec<f32> = a1.iter().flat_map(|&a| [a, a, a, a]).collect();
        let v4 = RoadVectors::from_theta_alpha(4, &t4, &a4).unwrap();

        for i in 0..2 * half {
            assert!((v1.r1[i] - v2.r1[i]).abs() < 1e-6);
            assert!((v1.r2[i] - v2.r2[i]).abs() < 1e-6);
            assert!((v2.r1[i] - v4.r1[i]).abs() < 1e-6);
            assert!((v2.r2[i] - v4.r2[i]).abs() < 1e-6);
        }
    }
}

#[test]
fn prop_merge_commutes_with_apply() {
    // x @ (W R^T) == R (x @ W) for random W, R, x (paper §3.2).
    let mut rng = Rng::seed_from(103);
    for _ in 0..CASES {
        let d_in = 1 + rng.below(6);
        let half = 1 + rng.below(6);
        let d_out = 2 * half;
        let w = HostTensor::f32(
            vec![d_in, d_out],
            (0..d_in * d_out).map(|_| rng.normal()).collect(),
        );
        let theta: Vec<f32> = (0..half).map(|_| rng.normal()).collect();
        let alpha: Vec<f32> = (0..half).map(|_| 1.0 + 0.2 * rng.normal()).collect();
        let v = RoadVectors::from_theta_alpha(1, &theta, &alpha).unwrap();
        let x: Vec<f32> = (0..d_in).map(|_| rng.normal()).collect();

        let wv = w.as_f32();
        let mut h = vec![0f32; d_out];
        for j in 0..d_out {
            for i in 0..d_in {
                h[j] += x[i] * wv[i * d_out + j];
            }
        }
        let want = road_rotate_vec(&h, &v.r1, &v.r2);
        let merged = road_merge_weight(&w, &v.r1, &v.r2);
        let mv = merged.as_f32();
        for j in 0..d_out {
            let mut got = 0f32;
            for i in 0..d_in {
                got += x[i] * mv[i * d_out + j];
            }
            assert!((got - want[j]).abs() < 1e-4, "{got} vs {}", want[j]);
        }
    }
}

#[test]
fn prop_compose_blocks_come_from_the_right_parent() {
    let cfg = tiny_cfg();
    let mut rng = Rng::seed_from(104);
    for case in 0..40 {
        let a = RoadAdapter::random(&cfg, &mut rng, 0.4);
        let b = RoadAdapter::random(&cfg, &mut rng, 0.4);
        let frac = (case % 5) as f32 / 4.0;
        let c = RoadAdapter::compose(&a, &b, frac).unwrap();
        for (k, vc) in &c.per_proj {
            let d = vc.dim();
            let split = road::adapters::subspace_split(d, frac);
            assert_eq!(&vc.r1[..split], &a.per_proj[k].r1[..split]);
            assert_eq!(&vc.r1[split..], &b.per_proj[k].r1[split..]);
            assert_eq!(&vc.r2[..split], &a.per_proj[k].r2[..split]);
            assert_eq!(&vc.r2[split..], &b.per_proj[k].r2[split..]);
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator state machines
// ---------------------------------------------------------------------------

#[test]
fn prop_slot_allocator_never_double_allocates() {
    let mut rng = Rng::seed_from(105);
    for _ in 0..CASES {
        let n = 1 + rng.below(16);
        let mut alloc = SlotAllocator::new(n);
        let mut held: Vec<usize> = Vec::new();
        for _ in 0..200 {
            if rng.chance(0.55) {
                if let Some(s) = alloc.alloc() {
                    assert!(!held.contains(&s), "slot {s} double-allocated");
                    assert!(s < n);
                    held.push(s);
                } else {
                    assert_eq!(held.len(), n, "alloc failed with free slots");
                }
            } else if !held.is_empty() {
                let i = rng.below(held.len());
                let s = held.swap_remove(i);
                alloc.release(s).unwrap();
                // Double release must error.
                assert!(alloc.release(s).is_err());
            }
            assert_eq!(alloc.n_free(), n - held.len());
        }
    }
}

#[test]
fn prop_queue_pop_fitting_preserves_order_and_bounds() {
    let mut rng = Rng::seed_from(106);
    for _ in 0..CASES {
        let mut q = AdmissionQueue::new(256);
        let n_items = rng.below(30);
        for i in 0..n_items {
            let plen = 1 + rng.below(20);
            // Ids are engine-issued in production; the property test
            // stamps them to check FIFO order below.
            let mut r = Request::new(vec![1; plen], 4);
            r.id = i as u64 + 1;
            q.push(r).unwrap();
        }
        let take = rng.below(8);
        let max_len = 1 + rng.below(20);
        let popped = q.pop_fitting(take, max_len);
        assert!(popped.len() <= take);
        assert!(popped.iter().all(|r| r.prompt.len() <= max_len));
        // Popped ids ascend (FIFO among selected).
        for w in popped.windows(2) {
            assert!(w[0].id < w[1].id);
        }
        // Everything is conserved.
        assert_eq!(popped.len() + q.len(), n_items);
    }
}

#[test]
fn prop_registry_paging_invariants() {
    // Random register / page-in / pin / unpin / evict / unregister
    // sequences over a bank with far fewer slots than adapters.  Checked
    // invariants:
    //  * registration always succeeds (the store is unbounded),
    //  * resident slots are unique, non-zero, and within the pageable
    //    range (never more residents than capacity),
    //  * a pinned adapter keeps its slot across arbitrary paging,
    //  * unregister/evict of a pinned adapter is rejected.
    let cfg = tiny_cfg();
    let mut rng = Rng::seed_from(107);
    for _case in 0..20 {
        let bank = AdapterBank::new(&cfg, "road", cfg.n_adapters).unwrap();
        let mut reg = AdapterRegistry::new(bank);
        let n_names = cfg.n_adapters * 3; // adapters >> slots
        for i in 0..n_names {
            let a = Adapter::Road(RoadAdapter::random(&cfg, &mut rng, 0.2));
            reg.register(&format!("u{i}"), &a).unwrap();
        }
        assert_eq!(reg.len(), n_names);

        let mut pinned: std::collections::BTreeMap<String, usize> = Default::default();
        for _op in 0..120 {
            let name = format!("u{}", rng.below(n_names));
            match rng.below(5) {
                // Page in (the admission path) and sometimes pin.
                0 | 1 => match reg.ensure_resident(&name) {
                    Ok(PageOutcome::Hit(slot)) | Ok(PageOutcome::Paged { slot, .. }) => {
                        assert!(slot > 0, "identity slot never paged");
                        if pinned.len() < reg.capacity() - 1 && rng.chance(0.5) {
                            reg.pin(slot);
                            *pinned.entry(name.clone()).or_insert(0) += 1;
                            // a double pin must also be safe
                            if rng.chance(0.25) {
                                reg.pin(slot);
                                *pinned.get_mut(&name).unwrap() += 1;
                            }
                        }
                    }
                    Ok(PageOutcome::Stalled) => {
                        assert!(
                            !pinned.is_empty(),
                            "stall without pinned slots is a pager bug"
                        );
                    }
                    Err(e) => panic!("ensure_resident({name}) failed: {e}"),
                },
                // Unpin one layer of a random pinned adapter.
                2 => {
                    if let Some(n) = pinned.keys().next().cloned() {
                        let slot = reg.slot_of(&n).expect("pinned implies resident");
                        reg.unpin(slot);
                        let left = pinned.get_mut(&n).unwrap();
                        *left -= 1;
                        if *left == 0 {
                            pinned.remove(&n);
                        }
                    }
                }
                // Evict: allowed iff not pinned; never touches the store.
                3 => {
                    if pinned.contains_key(&name) {
                        assert!(reg.evict(&name).is_err(), "evicted a pinned adapter");
                    } else {
                        let _ = reg.evict(&name).unwrap();
                        assert!(reg.store.contains(&name));
                    }
                }
                // Re-register: allowed iff not pinned.
                _ => {
                    let a = Adapter::Road(RoadAdapter::random(&cfg, &mut rng, 0.2));
                    if pinned.contains_key(&name) {
                        assert!(reg.register(&name, &a).is_err(), "replaced a pinned adapter");
                    } else {
                        reg.register(&name, &a).unwrap();
                    }
                }
            }
            // Invariants after every op.
            assert!(reg.resident_len() <= reg.capacity());
            let mut slots_seen = std::collections::BTreeSet::new();
            for n in reg.resident_names() {
                let s = reg.slot_of(n).unwrap();
                assert!(s > 0 && s < cfg.n_adapters, "slot {s} out of pageable range");
                assert!(slots_seen.insert(s), "slot {s} assigned twice");
            }
            for n in pinned.keys() {
                let s = reg.slot_of(n).expect("pinned adapter lost residency");
                assert!(reg.is_pinned(s));
            }
        }
    }
}

#[test]
fn prop_block_pool_conservation_under_random_ops() {
    // Random alloc / release / publish / ref / unref interleavings over a
    // small pool, mirrored against a model of what we hold.  Invariants,
    // checked after every op:
    //  * conservation: free + private + cached == n, and each block is in
    //    exactly one state (`check_conservation`),
    //  * no aliasing: an allocation never returns a block we already hold
    //    privately, nor one carrying a live reference,
    //  * eviction safety: only zero-reference cached blocks are ever
    //    evicted to satisfy an allocation,
    //  * the pool's gauges track the model exactly.
    // Honors `ROAD_PROPTEST_SEED` like the scheduler properties.
    let mut rng = Rng::seed_from(prop_seed() ^ 0xb10c);
    for _case in 0..60 {
        let n = 2 + rng.below(12);
        let mut pool = BlockPool::new(n, 4);
        let mut held: Vec<usize> = Vec::new(); // blocks we hold privately
        let mut cached: std::collections::BTreeMap<u64, (usize, usize)> = Default::default();
        let mut next_key = 1u64;
        for _op in 0..300 {
            match rng.below(10) {
                // Allocate a private block.
                0..=3 => match pool.alloc_private() {
                    Some(a) => {
                        assert!(!held.contains(&a.block), "aliased private block {}", a.block);
                        for (k, &(b, refs)) in &cached {
                            if refs > 0 {
                                assert_ne!(a.block, b, "allocated referenced block of key {k}");
                            }
                        }
                        if let Some(k) = a.evicted {
                            let (_, refs) = cached.remove(&k).expect("evicted unknown key");
                            assert_eq!(refs, 0, "evicted key {k} with live references");
                        }
                        held.push(a.block);
                    }
                    None => {
                        assert_eq!(pool.available(), 0, "stall with blocks available");
                    }
                },
                // Release a held block back to the free list.
                4 | 5 => {
                    if !held.is_empty() {
                        let b = held.swap_remove(rng.below(held.len()));
                        pool.release_private(b).unwrap();
                        // Exactly-once: the double release is a typed error
                        // that leaves the pool untouched.
                        let free_before = pool.n_free();
                        assert!(pool.release_private(b).is_err());
                        assert_eq!(pool.n_free(), free_before);
                    }
                }
                // Publish a held block under a fresh (or colliding) key.
                6 | 7 => {
                    if !held.is_empty() {
                        let i = rng.below(held.len());
                        let collide = !cached.is_empty() && rng.chance(0.3);
                        if collide {
                            // Duplicate key: the loser keeps its block private.
                            let k = *cached.keys().next().unwrap();
                            assert!(!pool.publish(held[i], k).unwrap());
                            assert!(pool.is_private(held[i]));
                        } else {
                            let k = next_key;
                            next_key += 1;
                            let b = held.swap_remove(i);
                            assert!(pool.publish(b, k).unwrap());
                            // The publisher keeps one reference.
                            cached.insert(k, (b, 1));
                        }
                    }
                }
                // Take a reference on a cached key (a shared-prefix hit).
                8 => {
                    if !cached.is_empty() {
                        let keys: Vec<u64> = cached.keys().copied().collect();
                        let k = keys[rng.below(keys.len())];
                        let entry = cached.get_mut(&k).unwrap();
                        assert_eq!(pool.ref_cached(k), Some(entry.0));
                        entry.1 += 1;
                    }
                }
                // Drop a reference (lane finish over a shared prefix).
                _ => {
                    let with_refs: Vec<u64> =
                        cached.iter().filter(|(_, v)| v.1 > 0).map(|(k, _)| *k).collect();
                    if !with_refs.is_empty() {
                        let k = with_refs[rng.below(with_refs.len())];
                        let entry = cached.get_mut(&k).unwrap();
                        pool.unref_cached(entry.0).unwrap();
                        entry.1 -= 1;
                        if entry.1 == 0 {
                            // Zero refs: the block stays cached (evictable),
                            // and a further unref is a typed error.
                            assert!(pool.unref_cached(entry.0).is_err());
                            assert!(pool.key_of(entry.0).is_some());
                        }
                    }
                }
            }
            pool.check_conservation().unwrap();
            assert_eq!(pool.n_private(), held.len());
            assert_eq!(pool.n_cached(), cached.len());
            assert_eq!(pool.total_refs(), cached.values().map(|v| v.1).sum::<usize>());
            for (k, &(b, refs)) in &cached {
                assert_eq!(pool.lookup(*k), Some(b));
                assert_eq!(pool.refs_of(b), refs);
            }
        }
    }
}

#[test]
fn prop_block_pool_release_paths_are_exactly_once() {
    // Every way a block leaves a lane is exactly-once, across random pool
    // shapes: double private release errors, releasing a published block
    // errors (it is no longer private), unref below zero errors, and a
    // fully-unreferenced published block is recyclable by allocation.
    let mut rng = Rng::seed_from(prop_seed() ^ 0x1d3a);
    for _case in 0..CASES {
        let n = 1 + rng.below(8);
        let mut pool = BlockPool::new(n, 1 + rng.below(8));
        let a = pool.alloc_private().unwrap();
        pool.release_private(a.block).unwrap();
        assert!(pool.release_private(a.block).is_err());

        let b = pool.alloc_private().unwrap().block;
        assert!(pool.publish(b, 7).unwrap());
        // Published: the private-release path must reject it...
        assert!(pool.release_private(b).is_err());
        // ...and the publisher's single reference unwinds exactly once.
        pool.unref_cached(b).unwrap();
        assert!(pool.unref_cached(b).is_err());
        pool.check_conservation().unwrap();
        // Unreferenced cached blocks are reclaimable: draining the pool
        // succeeds n times (the cached block is evicted on the way) and
        // the (n+1)-th allocation stalls.
        let mut got = Vec::new();
        for _ in 0..n {
            got.push(pool.alloc_private().expect("evictable block not reclaimed").block);
        }
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n, "drain aliased a block");
        assert!(pool.alloc_private().is_none());
        pool.check_conservation().unwrap();
    }
}

#[test]
fn prop_sched_conservation_under_random_ops() {
    // Random submit / cancel / clock-advance / step interleavings on the
    // deterministic harness, for every policy.  Invariants:
    //  * conservation: every submitted request is, at all times, exactly
    //    one of {terminal record, queued, in a lane} — and at the end,
    //    exactly one of finished / cancelled / shed,
    //  * capacity: the queue never exceeds its bound and active lanes
    //    never exceed the slot count,
    //  * sheds only happen to deadline-bearing requests, strictly after
    //    their budget elapsed on the virtual clock.
    let mut rng = Rng::seed_from(prop_seed() ^ 0x5c4ed);
    for kind in PolicyKind::ALL {
        for _case in 0..25 {
            let slots = 1 + rng.below(4);
            let cap = 4 + rng.below(12);
            let step_cost = Duration::from_millis(1 + rng.below(9) as u64);
            let mut sim = SchedSim::new(kind, slots, cap, step_cost);
            let mut submitted = 0usize;
            let mut cancelled = 0usize;
            let mut ids: Vec<u64> = Vec::new();
            for _op in 0..120 {
                match rng.below(10) {
                    0..=5 => {
                        let mut r = Request::new(vec![1; 1 + rng.below(8)], 1 + rng.below(6));
                        if rng.chance(0.4) {
                            r = r.with_deadline(Duration::from_millis(rng.below(40) as u64));
                        }
                        if rng.chance(0.3) {
                            r = r.with_priority(rng.below(4) as u8);
                        }
                        if rng.chance(0.5) {
                            r = r.with_adapter(&format!("a{}", rng.below(3)));
                        }
                        match sim.submit(r) {
                            Ok(id) => {
                                submitted += 1;
                                ids.push(id);
                            }
                            Err(EngineError::QueueFull { .. }) => {} // typed backpressure
                            Err(e) => panic!("unexpected submit error: {e}"),
                        }
                    }
                    6 => {
                        // Cancel a random known id; no-op (false) when it
                        // already reached a terminal record.
                        if !ids.is_empty() {
                            let id = ids[rng.below(ids.len())];
                            if sim.cancel(id) {
                                cancelled += 1;
                            }
                        }
                    }
                    7 => sim.clock.advance(Duration::from_millis(rng.below(20) as u64)),
                    _ => sim.step(),
                }
                assert!(sim.queue.len() <= cap, "queue exceeded its capacity bound");
                assert!(sim.n_active() <= slots, "more active lanes than decode slots");
                assert_eq!(
                    submitted,
                    sim.records().len() + sim.queue.len() + sim.n_active(),
                    "a request leaked or duplicated mid-run"
                );
            }
            sim.run_until_idle(4096);
            assert!(!sim.has_work(), "drain did not converge");
            assert_eq!(sim.records().len(), submitted, "terminal records != submissions");
            let mut seen = std::collections::BTreeSet::new();
            for r in sim.records() {
                assert!(seen.insert(r.id), "duplicate terminal record for id {}", r.id);
                if r.outcome == SimOutcome::DeadlineShed {
                    let dl = r.deadline.expect("only deadline-bearing requests can be shed");
                    assert!(
                        r.finished_at.duration_since(r.submitted_at) > dl,
                        "shed at {:?} within a {:?} budget (virtual clock)",
                        r.e2e(),
                        dl
                    );
                }
            }
            assert_eq!(
                sim.records().iter().filter(|r| r.outcome == SimOutcome::Cancelled).count(),
                cancelled,
                "cancellation count drifted"
            );
        }
    }
}

#[test]
fn prop_engine_lost_reservation_is_surfaced_never_silent() {
    // Conservation through the real engine's admission path under fault
    // injection: one request's KV reservation is made to vanish between
    // the gate and lane binding (the invariant breach that used to be a
    // silent drop — `else { continue }`, no event, a caller waiting
    // forever).  Every submitted id must still reach EXACTLY one terminal
    // event, and the victim's is the typed `internal` error.
    use std::rc::Rc;

    use road::coordinator::engine::{Engine, EngineConfig};
    use road::coordinator::request::{SamplingParams, StreamEvent};
    use road::runtime::{BackendKind, Runtime};
    use road::util::clock::Clock;

    let rt = Rc::new(
        Runtime::for_backend(BackendKind::Reference, road::Manifest::default_dir()).unwrap(),
    );
    let mut rng = Rng::seed_from(prop_seed() ^ 0x105e);
    // Each case runs a real engine to idle; 10 cases keep the test fast.
    for case in 0..10 {
        let clock = Clock::manual();
        let mut eng = Engine::new(
            rt.clone(),
            EngineConfig {
                model: "tiny".into(),
                mode: "base".into(),
                decode_slots: 2,
                queue_capacity: 64,
                clock: clock.clone(),
                ..Default::default()
            },
        )
        .unwrap();
        let n = 3 + rng.below(4);
        let mut ids = Vec::new();
        for i in 0..n {
            let plen = 2 + rng.below(6);
            let prompt: Vec<i32> =
                (0..plen).map(|p| 1 + ((case * 37 + i * 13 + p * 7) % 200) as i32).collect();
            let req = Request::new(prompt, 1 + rng.below(4)).with_sampling(SamplingParams {
                temperature: 0.0,
                top_k: 0,
                seed: 0,
                stop_token: None,
            });
            ids.push(eng.submit(req).unwrap());
        }
        let victim = ids[rng.below(ids.len())];
        eng.inject_reservation_loss(victim);
        // Drive step() directly: run_all treats any Error event as fatal,
        // and the property under test is that the engine itself keeps
        // serving the survivors.
        let mut terminal: std::collections::BTreeMap<u64, String> = Default::default();
        let mut steps = 0usize;
        while eng.has_work() {
            for ev in eng.step().unwrap() {
                match ev {
                    StreamEvent::Finished(o) => {
                        assert!(
                            terminal.insert(o.id, "finished".into()).is_none(),
                            "duplicate terminal event for id {}",
                            o.id
                        );
                    }
                    StreamEvent::Error { id, error } => {
                        assert!(
                            terminal.insert(id, error.kind().into()).is_none(),
                            "duplicate terminal event for id {id}"
                        );
                    }
                    StreamEvent::Admitted { .. } | StreamEvent::Token { .. } => {}
                }
            }
            clock.advance(Duration::from_millis(1));
            steps += 1;
            assert!(steps < 500, "engine wedged after injection");
        }
        assert_eq!(terminal.len(), n, "a request leaked without a terminal event");
        for id in &ids {
            let kind = terminal.get(id).expect("every submitted id gets a terminal event");
            if *id == victim {
                assert_eq!(kind, "internal", "victim must die loudly, not silently");
            } else {
                assert_eq!(kind, "finished", "survivor {id} must be unaffected");
            }
        }
    }
}

#[test]
fn prop_sched_rankings_are_permutations() {
    // Every policy's ranking is a permutation of the queue indices —
    // no request can be dropped or double-admitted by ordering alone.
    use road::coordinator::sched::{make_policy, SchedContext};
    use std::collections::BTreeMap;
    let mut rng = Rng::seed_from(prop_seed() ^ 0x9e4a);
    for kind in PolicyKind::ALL {
        for _case in 0..50 {
            let n = rng.below(20);
            let mut q = AdmissionQueue::new(64);
            for i in 0..n {
                let mut r = Request::new(vec![1; 1 + rng.below(6)], 2);
                r.id = i as u64 + 1;
                r.submitted_at = Some(std::time::Instant::now());
                if rng.chance(0.5) {
                    r.deadline = Some(Duration::from_millis(rng.below(100) as u64));
                }
                r.priority = rng.below(5) as u8;
                if rng.chance(0.5) {
                    r = r.with_adapter(&format!("a{}", rng.below(4)));
                }
                q.push(r).unwrap();
            }
            let mut in_flight: BTreeMap<String, usize> = BTreeMap::new();
            let mut admitted: BTreeMap<String, usize> = BTreeMap::new();
            for k in 0..4 {
                if rng.chance(0.5) {
                    in_flight.insert(format!("a{k}"), rng.below(3));
                    admitted.insert(format!("a{k}"), rng.below(50));
                }
            }
            let ctx = SchedContext {
                now: std::time::Instant::now(),
                in_flight: &in_flight,
                admitted: &admitted,
            };
            let order = make_policy(kind).order(&q, &ctx);
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(
                sorted,
                (0..n).collect::<Vec<_>>(),
                "[{kind:?}] ranking is not a permutation: {order:?}"
            );
        }
    }
}

#[test]
fn prop_placer_registry_invariants_under_random_ops() {
    // Random register / unregister / ready-flip / load-change / place
    // sequences against every placement policy.  Invariants:
    //  * the registry holds each adapter at most once, its home is in
    //    range, and its spill set excludes the home and has no duplicates,
    //  * a fresh registration homes on the ready replica with the fewest
    //    registered homes (ties to the lowest id), and fails only when no
    //    replica is ready,
    //  * `place` never targets a non-ready (draining/stopped) replica and
    //    returns None exactly when none is ready.
    let mut rng = Rng::seed_from(prop_seed() ^ 0x9047);
    for place in PlaceKind::ALL {
        for _case in 0..40 {
            let n = 1 + rng.below(5);
            let mut p = Placer::new(place, 1 + rng.below(6));
            let mut ready: Vec<bool> = vec![true; n];
            let mut loads: Vec<usize> = vec![0; n];
            let names: Vec<String> = (0..8).map(|i| format!("a{i}")).collect();
            for _op in 0..150 {
                let views: Vec<ReplicaView> = (0..n)
                    .map(|id| ReplicaView { id, ready: ready[id], load: loads[id] })
                    .collect();
                match rng.below(10) {
                    0 | 1 => {
                        let name = &names[rng.below(names.len())];
                        let fresh = !p.registry().contains_key(name.as_str());
                        // Home counts derived from the registry itself —
                        // the placer's internal counter must agree.
                        let counts: Vec<usize> = (0..n)
                            .map(|id| p.registry().values().filter(|pl| pl.home == id).count())
                            .collect();
                        match p.register(name, &views) {
                            Some(h) => {
                                if fresh {
                                    assert!(ready[h], "fresh home {h} not ready");
                                    let best = (0..n)
                                        .filter(|&id| ready[id])
                                        .min_by_key(|&id| (counts[id], id))
                                        .unwrap();
                                    assert_eq!(h, best, "fresh home is not balance-minimal");
                                }
                            }
                            None => {
                                assert!(fresh && ready.iter().all(|r| !r), "register refused");
                            }
                        }
                    }
                    2 => p.unregister(&names[rng.below(names.len())]),
                    3 => {
                        let i = rng.below(n);
                        ready[i] = !ready[i];
                    }
                    4 => {
                        let i = rng.below(n);
                        loads[i] = rng.below(12);
                    }
                    _ => {
                        let adapter = if rng.chance(0.7) {
                            Some(names[rng.below(names.len())].clone())
                        } else {
                            None
                        };
                        match p.place(adapter.as_deref(), &views) {
                            Some(t) => {
                                assert!(t < n, "placed out of range");
                                assert!(ready[t], "placed on a non-ready replica {t}");
                            }
                            None => {
                                assert!(ready.iter().all(|r| !r), "refused with a ready replica")
                            }
                        }
                    }
                }
                for (name, pl) in p.registry() {
                    assert!(pl.home < n, "{name}: home {} out of range", pl.home);
                    assert!(!pl.spill.contains(&pl.home), "{name}: home in its own spill set");
                    assert!(pl.spill.iter().all(|&r| r < n), "{name}: spill out of range");
                    let mut s = pl.spill.clone();
                    s.sort_unstable();
                    s.dedup();
                    assert_eq!(s.len(), pl.spill.len(), "{name}: duplicate spill entries");
                }
            }
        }
    }
}

#[test]
fn prop_fleet_sim_conservation_across_policies() {
    // Random submit / drain / step interleavings on the multi-replica sim,
    // for every placement policy.  Invariants, checked after every op:
    //  * conservation: every accepted submission is exactly one of
    //    {terminal record, queued, in a lane} across the fleet,
    //  * placement: accepted submissions never land on a replica that was
    //    draining at submit time, and a refusal happens only when every
    //    replica is draining,
    //  * the drain converges and the placement tally matches.
    let mut rng = Rng::seed_from(prop_seed() ^ 0xf1ee);
    for place in PlaceKind::ALL {
        for _case in 0..15 {
            let n = 1 + rng.below(4);
            let cfg = FleetSimConfig {
                place,
                n_replicas: n,
                decode_slots: 1 + rng.below(3),
                bank_slots: if rng.chance(0.5) { 2 } else { 0 },
                bank_row_bytes: 64,
                prefix_cache: if rng.chance(0.5) { 2 } else { 0 },
                prefix_len: 4,
                ..FleetSimConfig::default()
            };
            let mut fleet = FleetSim::new(&cfg);
            for a in 0..5 {
                fleet.register(&format!("a{a}"));
            }
            let mut drained = vec![false; n];
            let mut submitted = 0usize;
            for _op in 0..80 {
                match rng.below(8) {
                    0..=4 => {
                        let mut r = Request::new(vec![1; 1 + rng.below(8)], 1 + rng.below(4));
                        if rng.chance(0.7) {
                            r = r.with_adapter(&format!("a{}", rng.below(5)));
                        }
                        match fleet.submit(r) {
                            Ok((replica, _)) => {
                                assert!(replica < n);
                                assert!(!drained[replica], "placed on a draining replica");
                                submitted += 1;
                            }
                            Err(_) => {
                                assert!(drained.iter().all(|&d| d), "refused with a live replica");
                            }
                        }
                    }
                    5 => {
                        // Drains are rare so most cases keep a live fleet.
                        if rng.chance(0.3) {
                            let i = rng.below(n);
                            drained[i] = true;
                            fleet.drain(i);
                        }
                    }
                    _ => fleet.step(),
                }
                let in_system: usize = fleet
                    .replicas()
                    .iter()
                    .map(|s| s.records().len() + s.queue.len() + s.n_active())
                    .sum();
                assert_eq!(in_system, submitted, "a request leaked or duplicated mid-run");
            }
            fleet.run_until_idle(4096);
            assert!(!fleet.has_work(), "drain did not converge");
            let total: usize = fleet.replicas().iter().map(|s| s.records().len()).sum();
            assert_eq!(total, submitted, "terminal records != accepted submissions");
            assert_eq!(fleet.placed.iter().sum::<usize>(), submitted, "placement tally drifted");
        }
    }
}

#[test]
fn prop_sampler_greedy_is_argmax_and_topk_restricted() {
    let mut rng = Rng::seed_from(108);
    for _ in 0..CASES {
        let v = 4 + rng.below(60);
        let logits: Vec<f32> = (0..v).map(|_| rng.normal() * 3.0).collect();
        let argmax = logits
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        let mut s = Rng::seed_from(rng.next_u64());
        assert_eq!(sampler::sample(&logits, 0.0, 0, &mut s), argmax);

        // top-k sampling stays inside the top-k set.
        let k = 1 + rng.below(4);
        let mut sorted: Vec<(usize, f32)> =
            logits.iter().copied().enumerate().collect();
        sorted.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let allowed: std::collections::BTreeSet<i32> =
            sorted[..k].iter().map(|(i, _)| *i as i32).collect();
        for _ in 0..20 {
            let tok = sampler::sample(&logits, 1.0, k, &mut s);
            assert!(allowed.contains(&tok), "token {tok} outside top-{k}");
        }
    }
}

// ---------------------------------------------------------------------------
// Batch building / schedules
// ---------------------------------------------------------------------------

#[test]
fn prop_lm_batch_mask_iff_target_in_completion() {
    let mut rng = Rng::seed_from(109);
    for _ in 0..CASES {
        let l = 8 + rng.below(24);
        let plen = 1 + rng.below(6);
        let clen = 1 + rng.below(6);
        let prompt: Vec<i32> = (0..plen).map(|_| 1 + rng.below(250) as i32).collect();
        let completion: Vec<i32> = (0..clen).map(|_| 1 + rng.below(250) as i32).collect();
        let ex = Example { prompt: prompt.clone(), completion: completion.clone(), choices: vec![], answer: 0 };
        let b = lm_batch(&[ex], 1, l);
        let seq: Vec<i32> =
            prompt.iter().chain(&completion).copied().take(l).collect();
        for p in 0..l {
            let in_seq = p + 1 < seq.len().max(1);
            if in_seq {
                assert_eq!(b.targets[p], seq[p + 1], "target at {p}");
            }
            let predicts_completion = p + 1 >= plen && p + 1 < seq.len();
            assert_eq!(b.mask[p] > 0.0, predicts_completion, "mask at {p}");
        }
        // Masked positions always have nonzero targets (never PAD).
        for p in 0..l {
            if b.mask[p] > 0.0 {
                assert!(b.targets[p] > 0);
            }
        }
    }
}

#[test]
fn prop_linear_lr_bounded_and_continuous() {
    let mut rng = Rng::seed_from(110);
    for _ in 0..CASES {
        let total = 10 + rng.below(500);
        let peak = 0.1 + rng.f32();
        let mut prev = 0.0f32;
        for s in 0..total {
            let lr = linear_lr(s, total, 0.1, peak);
            assert!(lr >= 0.0 && lr <= peak * 1.0001, "lr {lr} peak {peak}");
            if s > 0 {
                // No jumps bigger than peak / (0.1 * total) + eps.
                let bound = peak / (0.1 * total as f32) + 1e-5;
                assert!((lr - prev).abs() <= bound, "jump {} at {s}", (lr - prev).abs());
            }
            prev = lr;
        }
    }
}

#[test]
fn prop_rng_fork_streams_are_independent() {
    let mut rng = Rng::seed_from(111);
    for _ in 0..50 {
        let seed = rng.next_u64();
        let mut a = Rng::seed_from(seed);
        let mut b = Rng::seed_from(seed);
        let fa = a.fork(1);
        let fb = b.fork(2);
        // Forks with different tags diverge; parents stay in sync.
        assert_eq!(a.next_u64(), b.next_u64());
        let mut fa = fa;
        let mut fb = fb;
        let same = (0..8).all(|_| fa.next_u64() == fb.next_u64());
        assert!(!same, "forked streams identical");
    }
}

#[test]
fn prop_fused_epilogue_matches_scalar() {
    // The fused (chunks_exact(8) + mul_add) epilogue drivers must agree
    // with the scalar oracle on random shapes: bitwise for road and ia3
    // (identical per-element arithmetic), within 1 ulp for lora (only the
    // z += mid*A drive changes iteration shape).  d_out alternates between
    // 8k (whole chunks) and 8k+2 (2-element remainder) to exercise both
    // the vector body and the scalar tail.
    let mut rng = Rng::seed_from(prop_seed() ^ 0xe91);
    for case in 0..CASES {
        let d_out = 8 * (1 + rng.below(4)) + if case % 2 == 0 { 0 } else { 2 };
        let d_in = 2 + rng.below(12);
        let rank = 1 + rng.below(4);
        let n_slots = 1 + rng.below(5);
        let rows = 1 + rng.below(9);
        let slots: Vec<usize> = (0..rows).map(|_| rng.below(n_slots)).collect();

        let r1 = rng.normal_vec(n_slots * d_out, 0.7);
        let r2 = rng.normal_vec(n_slots * d_out, 0.7);
        let z0 = rng.normal_vec(rows * d_out, 1.0);
        let r1v = BankView::new("p.r1", &r1, d_out).unwrap();
        let r2v = BankView::new("p.r2", &r2, d_out).unwrap();
        let (mut zs, mut zf) = (z0.clone(), z0.clone());
        epilogue::road(&mut zs, d_out, &slots, &r1v, &r2v, false).unwrap();
        epilogue::road(&mut zf, d_out, &slots, &r1v, &r2v, true).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&zs), bits(&zf), "road case {case} d_out {d_out}");

        let sv = BankView::new("p.s", &r1, d_out).unwrap();
        let (mut zs, mut zf) = (z0.clone(), z0.clone());
        epilogue::ia3(&mut zs, d_out, &slots, &sv, false).unwrap();
        epilogue::ia3(&mut zf, d_out, &slots, &sv, true).unwrap();
        assert_eq!(bits(&zs), bits(&zf), "ia3 case {case} d_out {d_out}");

        let lb = rng.normal_vec(n_slots * d_in * rank, 0.5);
        let la = rng.normal_vec(n_slots * rank * d_out, 0.5);
        let x = rng.normal_vec(rows * d_in, 1.0);
        let lbv = BankView::new("p.lb", &lb, d_in * rank).unwrap();
        let lav = BankView::new("p.la", &la, rank * d_out).unwrap();
        let (mut zs, mut zf) = (z0.clone(), z0);
        epilogue::lora(&mut zs, &x, d_in, d_out, rank, &slots, &lbv, &lav, false).unwrap();
        epilogue::lora(&mut zf, &x, d_in, d_out, rank, &slots, &lbv, &lav, true).unwrap();
        for (i, (a, b)) in zs.iter().zip(&zf).enumerate() {
            let ulps = (a.to_bits() as i64 - b.to_bits() as i64).abs();
            assert!(ulps <= 1, "lora case {case} elem {i}: {a} vs {b} ({ulps} ulps)");
        }
    }
}
