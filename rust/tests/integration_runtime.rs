//! End-to-end numerics: HLO artifacts produced by python/compile/aot.py,
//! loaded and executed through the rust PJRT runtime, compared against the
//! golden records computed by jax at artifact-build time.
//!
//! Without artifacts (`make artifacts`) every test skips cleanly.

use road::runtime::{allclose, buffer_to_host, Arg, Runtime};
use road::require_artifacts;

fn runtime() -> Runtime {
    Runtime::from_default_artifacts().expect("run `make artifacts` first")
}

#[test]
fn golden_decode_road() {
    require_artifacts!();
    let rt = runtime();
    let (ins, expected) = rt.load_golden("decode_road_tiny_b2").unwrap();
    let exe = rt.load("decode_road_tiny_b2").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    assert_eq!(outs.len(), expected.len());
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 1e-4, 1e-5).unwrap();
    }
}

/// `run_device` must agree with `run`: same entry, same inputs, device
/// outputs downloaded afterwards equal the host outputs (and the golden
/// record).  This is the runtime-level contract the device-resident decode
/// loop depends on.
#[test]
fn golden_decode_device_outputs_match_host_outputs() {
    require_artifacts!();
    let rt = runtime();
    let (ins, expected) = rt.load_golden("decode_road_tiny_b2").unwrap();
    let exe = rt.load("decode_road_tiny_b2").unwrap();

    // Mixed-residency call: upload the K/V cache inputs once and pass them
    // as persistent buffers, exactly like the engine's decode loop.
    let is_cache = |name: &str| name == "k_cache" || name == "v_cache";
    let mut bufs = Vec::new();
    for (t, spec) in ins.iter().zip(&exe.info.inputs) {
        if is_cache(&spec.name) {
            bufs.push(rt.upload(t).unwrap());
        }
    }
    let mut args: Vec<Arg> = Vec::new();
    let mut bi = 0;
    for (t, spec) in ins.iter().zip(&exe.info.inputs) {
        if is_cache(&spec.name) {
            args.push(Arg::Buffer(&bufs[bi]));
            bi += 1;
        } else {
            args.push(Arg::Host(t));
        }
    }

    let dev_outs = exe.run_device(&args).unwrap();
    assert_eq!(dev_outs.len(), expected.len());
    for ((buf, spec), e) in dev_outs.iter().zip(&exe.info.outputs).zip(&expected) {
        let host = buffer_to_host(buf, spec.dtype).unwrap();
        allclose(&host, e, 1e-4, 1e-5).unwrap();
    }
}

#[test]
fn golden_decode_base() {
    require_artifacts!();
    let rt = runtime();
    let (ins, expected) = rt.load_golden("decode_base_tiny_b2").unwrap();
    let exe = rt.load("decode_base_tiny_b2").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 1e-4, 1e-5).unwrap();
    }
}

#[test]
fn golden_prefill_road() {
    require_artifacts!();
    let rt = runtime();
    let (ins, expected) = rt.load_golden("prefill_road_tiny_b2_l16").unwrap();
    let exe = rt.load("prefill_road_tiny_b2_l16").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 1e-4, 1e-5).unwrap();
    }
}

#[test]
fn golden_train_step_road1() {
    require_artifacts!();
    let rt = runtime();
    let (ins, expected) = rt.load_golden("train_road1_tiny").unwrap();
    let exe = rt.load("train_road1_tiny").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    // train outputs include the loss scalar as the last element
    let loss = outs.last().unwrap().as_f32()[0];
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 2e-3, 1e-4).unwrap();
    }
}

#[test]
fn golden_eval_loss_road1() {
    require_artifacts!();
    let rt = runtime();
    let (ins, expected) = rt.load_golden("eval_loss_road1_tiny").unwrap();
    let exe = rt.load("eval_loss_road1_tiny").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 1e-3, 1e-5).unwrap();
    }
}

#[test]
fn executable_rejects_wrong_arity_and_shape() {
    require_artifacts!();
    let rt = runtime();
    let exe = rt.load("decode_base_tiny_b2").unwrap();
    assert!(exe.run_host(&[]).is_err());
    let (mut ins, _) = rt.load_golden("decode_base_tiny_b2").unwrap();
    // corrupt a shape
    let bad = road::HostTensor::f32(vec![1], vec![0.0]);
    ins[0] = bad;
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    assert!(exe.run_host(&refs).is_err());
}

#[test]
fn manifest_loads_and_entries_consistent() {
    require_artifacts!();
    let rt = runtime();
    assert!(rt.manifest.entries.len() >= 90, "{}", rt.manifest.entries.len());
    for cfg in ["tiny", "serve", "train", "train2"] {
        assert!(rt.manifest.configs.contains_key(cfg));
    }
    // decode buckets advertised by the manifest exist as entries
    for b in &rt.manifest.serve_decode_batches {
        for mode in ["base", "road", "lora"] {
            let name = format!("decode_{mode}_serve_b{b}");
            assert!(rt.manifest.entries.contains_key(&name), "{name}");
        }
    }
}
