//! Self-contained utilities (the offline image carries no general-purpose
//! crates beyond the xla closure; see DESIGN.md §Substitutions).

pub mod cli;
pub mod clock;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
