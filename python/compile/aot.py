"""AOT compiler: lower every Layer-1/Layer-2 entry point to HLO text.

`python -m compile.aot --out ../artifacts` produces:

  artifacts/<entry>.hlo.txt     HLO text per entry point (the interchange
                                format — jax >= 0.5 emits protos with
                                64-bit instruction ids that xla_extension
                                0.5.1 rejects; the text parser reassigns
                                ids, so text round-trips cleanly)
  artifacts/manifest.json       the contract with the rust runtime: model
                                configs, per-entry input/output signatures
                                (group, name, shape, dtype) in exact
                                positional order, and file inventory
  artifacts/params_<cfg>.bin    'pretrained' parameters, f32 LE, leaves
                                concatenated in flattening (sorted-key)
                                order
  artifacts/trainable_<cfg>_<method>.bin
                                method trainable init in flattening order
  artifacts/golden_<entry>.{in,out}.bin
                                recorded input/output tensors for rust
                                integration tests (raw LE bytes in
                                signature order)

Python runs once here and never on the request path.
"""

import argparse
import json
import os
import re
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model, train
from .kernels import ref as kref

F32, I32 = "f32", "i32"
_DTYPES = {F32: jnp.float32, I32: jnp.int32}
_NPDT = {F32: np.float32, I32: np.int32}

# Shape bucket constants (mirrored in rust via the manifest).
SERVE_DECODE_BATCHES = (1, 2, 4, 8, 16)
SERVE_PREFILL_BUCKETS = ((1, 16), (8, 16), (8, 64))
TINY_PREFILL = (2, 16)
TRAIN_B, TRAIN_L = 16, 32
GEN_B, GEN_L = 8, 16
REPS_B, REPS_L = 16, 32
HEAD_B, HEAD_K = 64, 4

SERVE_MODES = ("base", "road", "lora")
GEN_MODES = ("base", "road", "lora", "ia3", "oft")
EVAL_METHODS = ("full", "road1", "road2", "road4", "road1_fc1", "lora",
                "ia3", "bitfit", "oft2", "oft16")
TRAIN2_METHODS = ("road1", "road2", "road4", "lora", "full")


def spec(group, name, shape, dtype=F32):
    return {"group": group, "name": name, "shape": list(shape),
            "dtype": dtype}


def sds(s):
    return jax.ShapeDtypeStruct(tuple(s["shape"]), _DTYPES[s["dtype"]])


class Entry:
    """One lowered entry point: flat positional fn + signature + metadata."""

    def __init__(self, name, fn, inputs, meta):
        self.name = name
        self.fn = fn
        self.inputs = inputs  # list of spec dicts, positional order
        self.meta = meta      # kind/mode/config/... (copied into manifest)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Entry builders
# ---------------------------------------------------------------------------

def _dict_specs(group, named_shapes, dtype=F32):
    return [spec(group, n, s, dtype) for n, s in named_shapes]


def serving_entry(kind, cfg, mode, b, l=None):
    """prefill_<mode>_<cfg>_b<B>_l<L> / decode_<mode>_<cfg>_b<B>."""
    pspecs = _dict_specs("params", model.param_specs(cfg))
    aspecs = _dict_specs("adapters", model.adapter_specs(cfg, mode))
    np_, na = len(pspecs), len(aspecs)
    nl, h, t, hd = cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim
    pkeys = [s["name"] for s in pspecs]
    akeys = [s["name"] for s in aspecs]

    if kind == "prefill":
        data = [spec("data", "ids", (b,), I32),
                spec("data", "tokens", (b, l), I32),
                spec("data", "lengths", (b,), I32)]

        def fn(*args):
            p = model.unflatten(pkeys, args[:np_])
            a = model.unflatten(akeys, args[np_:np_ + na])
            ids, tokens, lengths = args[np_ + na:]
            return model.prefill(cfg, mode, p, a, ids, tokens, lengths)

        name = f"prefill_{mode}_{cfg.name}_b{b}_l{l}"
    else:
        data = [spec("data", "ids", (b,), I32),
                spec("data", "token", (b,), I32),
                spec("data", "pos", (b,), I32),
                spec("data", "k_cache", (nl, b, h, t, hd), F32),
                spec("data", "v_cache", (nl, b, h, t, hd), F32)]

        def fn(*args):
            p = model.unflatten(pkeys, args[:np_])
            a = model.unflatten(akeys, args[np_:np_ + na])
            ids, token, pos, kc, vc = args[np_ + na:]
            return model.decode(cfg, mode, p, a, ids, token, pos, kc, vc)

        name = f"decode_{mode}_{cfg.name}_b{b}"

    meta = {"kind": kind, "mode": mode, "config": cfg.name, "batch": b}
    if l is not None:
        meta["prompt_len"] = l
    return Entry(name, fn, pspecs + aspecs + data, meta)


def train_entry(cfg, method, b=TRAIN_B, l=TRAIN_L):
    frozen_specs = [] if method == "full" \
        else _dict_specs("frozen", model.param_specs(cfg))
    tspecs = _dict_specs("trainable", train.trainable_specs(cfg, method))
    mspecs = [dict(s, group="opt_m") for s in tspecs]
    vspecs = [dict(s, group="opt_v") for s in tspecs]
    masked = method == "road1_masked"
    gspecs = [dict(s, group="grad_mask") for s in tspecs] if masked else []
    data = [spec("data", "step", (), F32), spec("data", "lr", (), F32),
            spec("data", "tokens", (b, l), I32),
            spec("data", "targets", (b, l), I32),
            spec("data", "mask", (b, l), F32)]
    nf, nt = len(frozen_specs), len(tspecs)
    fkeys = [s["name"] for s in frozen_specs]
    tkeys = [s["name"] for s in tspecs]

    def fn(*args):
        i = 0
        frozen = model.unflatten(fkeys, args[i:i + nf]); i += nf
        tr = model.unflatten(tkeys, args[i:i + nt]); i += nt
        m = model.unflatten(tkeys, args[i:i + nt]); i += nt
        v = model.unflatten(tkeys, args[i:i + nt]); i += nt
        gm = None
        if masked:
            gm = model.unflatten(tkeys, args[i:i + nt]); i += nt
        step, lr, tokens, targets, mask = args[i:]
        nt_, nm_, nv_, loss = train.train_step(
            cfg, method, frozen, tr, m, v, step, lr, tokens, targets, mask,
            grad_mask=gm)
        return (*model.flatten(nt_), *model.flatten(nm_),
                *model.flatten(nv_), loss)

    meta = {"kind": "train_step", "method": method, "config": cfg.name,
            "batch": b, "seq_len": l,
            "n_trainable": int(sum(int(np.prod(s["shape"])) for s in tspecs))}
    return Entry(f"train_{method}_{cfg.name}", fn,
                 frozen_specs + tspecs + mspecs + vspecs + gspecs + data,
                 meta)


def eval_entry(kind, cfg, method, b=TRAIN_B, l=TRAIN_L):
    frozen_specs = [] if method == "full" \
        else _dict_specs("frozen", model.param_specs(cfg))
    tspecs = _dict_specs("trainable", train.trainable_specs(cfg, method))
    nf, nt = len(frozen_specs), len(tspecs)
    fkeys = [s["name"] for s in frozen_specs]
    tkeys = [s["name"] for s in tspecs]
    if kind == "eval_loss":
        data = [spec("data", "tokens", (b, l), I32),
                spec("data", "targets", (b, l), I32),
                spec("data", "mask", (b, l), F32)]

        def fn(*args):
            frozen = model.unflatten(fkeys, args[:nf])
            tr = model.unflatten(tkeys, args[nf:nf + nt])
            tokens, targets, mask = args[nf + nt:]
            return train.eval_loss(cfg, method, frozen, tr, tokens, targets,
                                   mask)
    else:
        data = [spec("data", "tokens", (b, l), I32),
                spec("data", "lengths", (b,), I32)]

        def fn(*args):
            frozen = model.unflatten(fkeys, args[:nf])
            tr = model.unflatten(tkeys, args[nf:nf + nt])
            tokens, lengths = args[nf + nt:]
            return (train.last_logits(cfg, method, frozen, tr, tokens,
                                      lengths),)

    meta = {"kind": kind, "method": method, "config": cfg.name, "batch": b,
            "seq_len": l}
    return Entry(f"{kind}_{method}_{cfg.name}", fn,
                 frozen_specs + tspecs + data, meta)


def reps_entry(cfg, mode, b=REPS_B, l=REPS_L):
    pspecs = _dict_specs("params", model.param_specs(cfg))
    aspecs = _dict_specs("adapters", model.adapter_specs(cfg, mode, n=1))
    np_, na = len(pspecs), len(aspecs)
    pkeys = [s["name"] for s in pspecs]
    akeys = [s["name"] for s in aspecs]
    data = [spec("data", "ids", (b,), I32),
            spec("data", "tokens", (b, l), I32),
            spec("data", "lengths", (b,), I32)]

    def fn(*args):
        p = model.unflatten(pkeys, args[:np_])
        a = model.unflatten(akeys, args[np_:np_ + na])
        ids, tokens, lengths = args[np_ + na:]
        return (model.hidden_states(cfg, mode, p, a, ids, tokens, lengths),)

    meta = {"kind": "reps", "mode": mode, "config": cfg.name, "batch": b,
            "seq_len": l}
    return Entry(f"reps_{mode}_{cfg.name}", fn, pspecs + aspecs + data, meta)


def head_entry(kind, cfg, head_mode, b=HEAD_B, k=HEAD_K):
    d = cfg.d_model
    hspecs = _dict_specs("trainable", [("b1", (d,)), ("b2", (k,)),
                                       ("w1", (d, d)), ("w2", (d, k))])
    hkeys = [s["name"] for s in hspecs]
    if kind == "head_train":
        mspecs = [dict(s, group="opt_m") for s in hspecs]
        vspecs = [dict(s, group="opt_v") for s in hspecs]
        data = [spec("data", "step", (), F32), spec("data", "lr", (), F32),
                spec("data", "reps", (b, d), F32),
                spec("data", "labels", (b,), I32)]

        def fn(*args):
            hd = model.unflatten(hkeys, args[0:4])
            m = model.unflatten(hkeys, args[4:8])
            v = model.unflatten(hkeys, args[8:12])
            step, lr, reps, labels = args[12:]
            nh, nm, nv, loss = train.head_train_step(hd, m, v, step, lr,
                                                     reps, labels, head_mode)
            return (*model.flatten(nh), *model.flatten(nm),
                    *model.flatten(nv), loss)

        inputs = hspecs + mspecs + vspecs + data
    else:
        data = [spec("data", "reps", (b, d), F32)]

        def fn(*args):
            hd = model.unflatten(hkeys, args[0:4])
            return (train.head_logits(hd, args[4], head_mode),)

        inputs = hspecs + data
    meta = {"kind": kind, "head_mode": head_mode, "config": cfg.name,
            "batch": b, "n_classes": k}
    return Entry(f"{kind}_{head_mode}_{cfg.name}", fn, inputs, meta)


def build_all_entries():
    entries = []
    serve, tiny, tr, tr2 = (configs.SERVE, configs.TINY, configs.TRAIN,
                            configs.TRAIN2)
    # Serving (Figure 4 / the coordinator's hot path)
    for mode in SERVE_MODES:
        for b in SERVE_DECODE_BATCHES:
            entries.append(serving_entry("decode", serve, mode, b))
        for b, l in SERVE_PREFILL_BUCKETS:
            entries.append(serving_entry("prefill", serve, mode, b, l))
    # Tiny (unit/integration scale)
    for mode in SERVE_MODES:
        entries.append(serving_entry("decode", tiny, mode, TINY_PREFILL[0]))
        entries.append(serving_entry("prefill", tiny, mode, *TINY_PREFILL))
    entries.append(train_entry(tiny, "road1", b=4, l=16))
    entries.append(eval_entry("eval_loss", tiny, "road1", b=4, l=16))
    entries.append(eval_entry("last_logits", tiny, "road1", b=4, l=16))
    # Training graphs (Tables 2-6, Fig 2/5, Tab D.1)
    for method in train.METHODS:
        entries.append(train_entry(tr, method))
    for method in EVAL_METHODS:
        entries.append(eval_entry("eval_loss", tr, method))
        entries.append(eval_entry("last_logits", tr, method))
    # Generative eval on the train config (commonsense/arithmetic suites,
    # composability generation): adapter banks with n_adapters slots.
    for mode in GEN_MODES:
        entries.append(serving_entry("prefill", tr, mode, GEN_B, GEN_L))
        entries.append(serving_entry("decode", tr, mode, GEN_B))
    # Pilot studies
    for mode in ("base", "road", "lora"):
        entries.append(reps_entry(tr, mode))
    for hm in train.HEAD_MODES:
        entries.append(head_entry("head_train", tr, hm))
        entries.append(head_entry("head_logits", tr, hm))
    # Second backbone (Tab D.2 analogue)
    for method in TRAIN2_METHODS:
        entries.append(train_entry(tr2, method))
        entries.append(eval_entry("eval_loss", tr2, method))
        entries.append(eval_entry("last_logits", tr2, method))
    return entries


# ---------------------------------------------------------------------------
# Binary dumps (params, trainable inits, golden records)
# ---------------------------------------------------------------------------

def dump_flat(path, arrays):
    with open(path, "wb") as f:
        for a in arrays:
            f.write(np.asarray(a).astype(_NPDT[F32], copy=False).tobytes())


def dump_params(out):
    files = {}
    for cfg in configs.PRESETS.values():
        p = model.init_params(cfg, jax.random.PRNGKey(0))
        fname = f"params_{cfg.name}.bin"
        dump_flat(os.path.join(out, fname), model.flatten(p))
        files[cfg.name] = fname
    return files


def dump_trainables(out):
    files = {}
    jobs = [(configs.TRAIN, m) for m in train.METHODS]
    jobs += [(configs.TRAIN2, m) for m in TRAIN2_METHODS]
    jobs += [(configs.TINY, "road1")]
    for cfg, method in jobs:
        p = model.init_params(cfg, jax.random.PRNGKey(0))
        t = train.init_trainable(cfg, method, jax.random.PRNGKey(7), p)
        fname = f"trainable_{cfg.name}_{method}.bin"
        dump_flat(os.path.join(out, fname), model.flatten(t))
        files[f"{cfg.name}/{method}"] = fname
    return files


def _golden_inputs(entry, rng):
    """Deterministic concrete inputs for a golden record."""
    arrs = []
    cfg = configs.PRESETS[entry.meta["config"]]
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    for s in entry.inputs:
        if s["group"] in ("params", "frozen"):
            arrs.append(np.asarray(params[s["name"]], dtype=np.float32))
        elif s["group"] == "adapters" and s["name"].endswith(".r1"):
            n, d = s["shape"]
            theta = 0.1 + 0.05 * np.arange(d // 2, dtype=np.float32)
            r1 = np.repeat(np.cos(theta), 2)
            arrs.append(np.tile(r1, (n, 1)).astype(np.float32))
        elif s["group"] == "adapters" and s["name"].endswith(".r2"):
            n, d = s["shape"]
            theta = 0.1 + 0.05 * np.arange(d // 2, dtype=np.float32)
            r2 = np.repeat(np.sin(theta), 2)
            arrs.append(np.tile(r2, (n, 1)).astype(np.float32))
        elif s["dtype"] == I32:
            if s["name"] in ("ids",):
                arrs.append((np.arange(int(np.prod(s["shape"])))
                             % 2).reshape(s["shape"]).astype(np.int32))
            elif s["name"] in ("tokens", "token", "targets"):
                arrs.append(rng.integers(
                    0, cfg.vocab, size=s["shape"]).astype(np.int32))
            elif s["name"] in ("lengths", "pos"):
                arrs.append(np.full(s["shape"], 7, dtype=np.int32))
            elif s["name"] == "labels":
                arrs.append(rng.integers(0, 4, s["shape"]).astype(np.int32))
            else:
                arrs.append(np.zeros(s["shape"], dtype=np.int32))
        else:
            if s["name"] == "mask":
                arrs.append(np.ones(s["shape"], dtype=np.float32))
            elif s["name"] in ("k_cache", "v_cache"):
                arrs.append((0.01 * rng.standard_normal(s["shape"]))
                            .astype(np.float32))
            elif s["name"] == "step":
                arrs.append(np.float32(1.0))
            elif s["name"] == "lr":
                arrs.append(np.float32(1e-3))
            elif s["group"] in ("opt_m", "opt_v"):
                arrs.append(np.zeros(s["shape"], dtype=np.float32))
            elif s["group"] == "grad_mask":
                arrs.append(np.ones(s["shape"], dtype=np.float32))
            elif s["group"] == "trainable":
                # identity-ish values from the dumped trainable init
                t = train.init_trainable(
                    cfg, entry.meta.get("method", "road1"),
                    jax.random.PRNGKey(7), params)
                arrs.append(np.asarray(t[s["name"]], dtype=np.float32))
            else:
                arrs.append((0.1 * rng.standard_normal(s["shape"]))
                            .astype(np.float32))
    return arrs


GOLDEN_ENTRIES = ("decode_road_tiny_b2", "prefill_road_tiny_b2_l16",
                  "decode_base_tiny_b2", "train_road1_tiny",
                  "eval_loss_road1_tiny")


def dump_golden(out, entries):
    by_name = {e.name: e for e in entries}
    golden = {}
    for name in GOLDEN_ENTRIES:
        e = by_name[name]
        rng = np.random.default_rng(1234)
        ins = _golden_inputs(e, rng)
        outs = e.fn(*[jnp.asarray(a) for a in ins])
        with open(os.path.join(out, f"golden_{name}.in.bin"), "wb") as f:
            for a in ins:
                f.write(np.asarray(a).tobytes())
        out_specs = []
        with open(os.path.join(out, f"golden_{name}.out.bin"), "wb") as f:
            for i, o in enumerate(outs):
                o = np.asarray(o)
                out_specs.append({"name": f"out{i}", "shape": list(o.shape),
                                  "dtype": F32 if o.dtype == np.float32
                                  else I32})
                f.write(o.tobytes())
        golden[name] = {"in": f"golden_{name}.in.bin",
                        "out": f"golden_{name}.out.bin",
                        "outputs": out_specs}
    return golden


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def lower_entry(entry, out_dir):
    in_sds = [sds(s) for s in entry.inputs]
    t0 = time.time()
    lowered = jax.jit(entry.fn, keep_unused=True).lower(*in_sds)
    out_shapes = jax.eval_shape(entry.fn, *in_sds)
    text = to_hlo_text(lowered)
    fname = f"{entry.name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    outputs = []
    for i, o in enumerate(out_shapes):
        dt = F32 if o.dtype == jnp.float32 else I32
        outputs.append({"name": f"out{i}", "shape": list(o.shape),
                        "dtype": dt})
    return {"file": fname, "inputs": entry.inputs, "outputs": outputs,
            **entry.meta}, time.time() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="regex filter on entry names (incremental build)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = build_all_entries()
    existing = None
    if args.only:
        pat = re.compile(args.only)
        entries = [e for e in entries if pat.search(e.name)]
        # Incremental build: merge into the existing manifest instead of
        # clobbering entries outside the filter.
        mpath = os.path.join(args.out, "manifest.json")
        if os.path.exists(mpath):
            with open(mpath) as f:
                existing = json.load(f)

    manifest = {
        "configs": {c.name: c.to_dict() for c in configs.PRESETS.values()},
        "buckets": {
            "serve_decode_batches": list(SERVE_DECODE_BATCHES),
            "serve_prefill": [list(b) for b in SERVE_PREFILL_BUCKETS],
            "train": {"batch": TRAIN_B, "seq_len": TRAIN_L},
            "gen": {"batch": GEN_B, "prompt_len": GEN_L},
            "reps": {"batch": REPS_B, "seq_len": REPS_L},
            "head": {"batch": HEAD_B, "n_classes": HEAD_K},
        },
        "entries": {},
    }
    total = len(entries)
    for i, e in enumerate(entries):
        meta, dt = lower_entry(e, args.out)
        manifest["entries"][e.name] = meta
        print(f"[{i + 1}/{total}] {e.name}  ({dt:.1f}s)", flush=True)

    if existing is not None:
        # Keep untouched entries/dumps; refresh only what we rebuilt.
        merged = dict(existing)
        merged["entries"].update(manifest["entries"])
        merged["configs"] = manifest["configs"]
        merged["buckets"] = manifest["buckets"]
        merged["params_files"] = dump_params(args.out)
        merged["trainable_files"] = dump_trainables(args.out)
        manifest = merged
    else:
        manifest["params_files"] = dump_params(args.out)
        manifest["trainable_files"] = dump_trainables(args.out)
        manifest["golden"] = dump_golden(args.out, build_all_entries())
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {total} entries + manifest to {args.out}")


if __name__ == "__main__":
    main()
