//! Model host: parameter store + host-side weight merging.
//!
//! Parameters live as flat named tensors in manifest flattening order
//! (sorted keys — the contract with python/compile/model.py).  Merging
//! folds trained adapters into the pretrained weights (paper §3.2:
//! W = W⁰ Rᵀ for RoAd, W = W⁰ + BA for LoRA) so the merged model serves
//! through the zero-overhead `base` entries.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::manifest::{Manifest, ModelConfigInfo};
use crate::tensor::{load_flat_f32, HostTensor};

/// Projections adapted by RoAd (every linear layer of a block).
pub const PROJS: [&str; 7] = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"];

pub fn proj_dims(cfg: &ModelConfigInfo, proj: &str) -> (usize, usize) {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    match proj {
        "wq" | "wk" | "wv" | "wo" => (d, d),
        "wgate" | "wup" => (d, f),
        "wdown" => (f, d),
        _ => panic!("unknown projection {proj}"),
    }
}

#[derive(Clone)]
pub struct ParamStore {
    pub config: ModelConfigInfo,
    pub names: Vec<String>,
    pub tensors: Vec<HostTensor>,
    index: HashMap<String, usize>,
}

impl ParamStore {
    /// Load the 'pretrained' parameters for `config` from params_<cfg>.bin.
    ///
    /// The (name, shape) specs are recovered from any manifest entry of this
    /// config that declares a `params` (or `frozen`) input group.  On a
    /// synthetic manifest (the artifact-free reference backend) the
    /// parameters are generated deterministically instead of read from disk.
    pub fn load(manifest: &Manifest, config: &str) -> Result<ParamStore> {
        let cfg = manifest.config(config)?.clone();
        let specs = param_specs(manifest, config)?;
        if manifest.synthetic {
            let generated = crate::runtime::reference::synthetic_params(&cfg, &specs);
            return Ok(ParamStore::from_tensors(cfg, generated));
        }
        let file = manifest
            .params_files
            .get(config)
            .ok_or_else(|| anyhow!("no params file for config {config}"))?;
        let bytes = std::fs::read(manifest.artifact_path(file))?;
        let loaded = load_flat_f32(&bytes, &specs)?;
        Ok(ParamStore::from_tensors(cfg, loaded))
    }

    /// Load the backbone that finetuning starts from: the full-finetuned
    /// pretraining checkpoint `pretrained_<cfg>.bin` when present (written
    /// by `road pretrain`), else the random-init `params_<cfg>.bin` (or the
    /// deterministic synthetic init on a synthetic manifest).
    ///
    /// The paper's PEFT methods adapt a *pretrained* LLM; the pretraining
    /// stage is part of this reproduction's system (DESIGN.md §4).
    pub fn load_pretrained(manifest: &Manifest, config: &str) -> Result<ParamStore> {
        if !manifest.synthetic {
            let cand = manifest.artifact_path(&format!("pretrained_{config}.bin"));
            if cand.exists() {
                let cfg = manifest.config(config)?.clone();
                let specs = param_specs(manifest, config)?;
                let bytes = std::fs::read(&cand)?;
                let loaded = load_flat_f32(&bytes, &specs)?;
                return Ok(ParamStore::from_tensors(cfg, loaded));
            }
        }
        ParamStore::load(manifest, config)
    }

    /// Save this store in the flat pretrained-checkpoint format.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let refs: Vec<&HostTensor> = self.tensors.iter().collect();
        std::fs::write(path, crate::tensor::dump_flat(&refs))?;
        Ok(())
    }

    pub fn from_tensors(
        config: ModelConfigInfo,
        named: Vec<(String, HostTensor)>,
    ) -> ParamStore {
        let mut names = Vec::new();
        let mut tensors = Vec::new();
        let mut index = HashMap::new();
        for (n, t) in named {
            index.insert(n.clone(), tensors.len());
            names.push(n);
            tensors.push(t);
        }
        ParamStore { config, names, tensors, index }
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.index
            .get(name)
            .map(|i| &self.tensors[*i])
            .ok_or_else(|| anyhow!("no parameter {name:?}"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut HostTensor> {
        let i = *self.index.get(name).ok_or_else(|| anyhow!("no parameter {name:?}"))?;
        Ok(&mut self.tensors[i])
    }

    pub fn set(&mut self, name: &str, t: HostTensor) -> Result<()> {
        let i = *self.index.get(name).ok_or_else(|| anyhow!("no parameter {name:?}"))?;
        if self.tensors[i].shape != t.shape {
            bail!("shape mismatch setting {name}");
        }
        self.tensors[i] = t;
        Ok(())
    }

    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(|t| t.elem_count()).sum()
    }

    /// Merge a RoAd adapter into every adapted projection (paper §3.2):
    /// W <- W Rᵀ, bias <- R bias.  Leaves the store serving-ready through
    /// the zero-overhead `base` entries.
    pub fn merge_road(&mut self, adapter: &crate::adapters::RoadAdapter) -> Result<()> {
        for (key, vecs) in &adapter.per_proj {
            let w = self.get(key)?.clone();
            let merged = road_merge_weight(&w, &vecs.r1, &vecs.r2);
            self.set(key, merged)?;
            let bkey = format!("{key}.bias");
            let b = self.get(&bkey)?.clone();
            let merged_b = road_rotate_vec(&b.as_f32(), &vecs.r1, &vecs.r2);
            self.set(&bkey, HostTensor::f32(b.shape.clone(), merged_b))?;
        }
        Ok(())
    }

    /// Merge a LoRA adapter: W <- W + lb @ la.
    pub fn merge_lora(&mut self, adapter: &crate::adapters::LoraAdapter) -> Result<()> {
        for (key, m) in &adapter.per_proj {
            let w = self.get(key)?.clone();
            let merged = lora_merge_weight(&w, &m.lb, &m.la, m.rank);
            self.set(key, merged)?;
        }
        Ok(())
    }
}

/// Recover the param flattening specs for a config from the manifest.
pub fn param_specs(manifest: &Manifest, config: &str) -> Result<Vec<(String, Vec<usize>)>> {
    for e in manifest.entries.values() {
        if e.config != config {
            continue;
        }
        for group in ["params", "frozen"] {
            let (start, end) = e.group_range(group);
            if end > start {
                return Ok(e.inputs[start..end]
                    .iter()
                    .map(|s| (s.name.clone(), s.shape.clone()))
                    .collect());
            }
        }
        // "full" train entries carry params as the trainable group.
        if e.method.as_deref() == Some("full") {
            let (start, end) = e.group_range("trainable");
            if end > start {
                return Ok(e.inputs[start..end]
                    .iter()
                    .map(|s| (s.name.clone(), s.shape.clone()))
                    .collect());
            }
        }
    }
    bail!("no entry with a params group for config {config}")
}

/// z = R h for the sparse block-diagonal R given by effective vectors
/// (r1, r2): z = r1*h + r2*pairswap(h).  Host-side oracle used by merging
/// and by the runtime tests.
pub fn road_rotate_vec(h: &[f32], r1: &[f32], r2: &[f32]) -> Vec<f32> {
    let mut z = h.to_vec();
    crate::runtime::epilogue::rotate_row_fused(&mut z, r1, r2);
    z
}

/// Fold (r1, r2) into W [d_in, d_out] (inputs-left convention): W' = W Rᵀ.
///
/// Column pairs transform as:
///   W'[:, 2k]   = r1[2k]   * W[:, 2k] − r2[2k]   * W[:, 2k+1]
///   W'[:, 2k+1] = r2[2k+1] * W[:, 2k] + r1[2k+1] * W[:, 2k+1]
pub fn road_merge_weight(w: &HostTensor, r1: &[f32], r2: &[f32]) -> HostTensor {
    let (d_in, d_out) = (w.shape[0], w.shape[1]);
    let mut out = w.as_f32();
    // Each weight row's column pairs transform exactly like an activation
    // row under Eq. 4, so the merge shares the serving rotation kernel
    // (one source of truth for the pair arithmetic).
    for i in 0..d_in {
        crate::runtime::epilogue::rotate_row_fused(&mut out[i * d_out..(i + 1) * d_out], r1, r2);
    }
    HostTensor::f32(w.shape.clone(), out)
}

/// W' = W + lb @ la with lb [d_in, r] and la [r, d_out] (flat slices).
pub fn lora_merge_weight(w: &HostTensor, lb: &[f32], la: &[f32], rank: usize) -> HostTensor {
    let (d_in, d_out) = (w.shape[0], w.shape[1]);
    assert_eq!(lb.len(), d_in * rank);
    assert_eq!(la.len(), rank * d_out);
    let mut out = w.as_f32();
    for i in 0..d_in {
        for r in 0..rank {
            let b = lb[i * rank + r];
            if b == 0.0 {
                continue;
            }
            let arow = r * d_out;
            let orow = i * d_out;
            for j in 0..d_out {
                out[orow + j] += b * la[arow + j];
            }
        }
    }
    HostTensor::f32(w.shape.clone(), out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rotate_identity() {
        let h = vec![1.0, 2.0, 3.0, 4.0];
        let r1 = vec![1.0; 4];
        let r2 = vec![0.0; 4];
        assert_eq!(road_rotate_vec(&h, &r1, &r2), h);
    }

    #[test]
    fn rotate_quarter_turn() {
        // theta = pi/2: r1 = 0, r2 = 1 -> z = pairswap(h) = (-h2, h1, ...)
        let h = vec![1.0, 2.0, 3.0, 4.0];
        let r1 = vec![0.0; 4];
        let r2 = vec![1.0; 4];
        assert_eq!(road_rotate_vec(&h, &r1, &r2), vec![-2.0, 1.0, -4.0, 3.0]);
    }

    #[test]
    fn merge_equals_rotate_after_matmul() {
        // x @ (W R^T) == R (x @ W) for random-ish data.
        let d_in = 3;
        let d_out = 4;
        let w = HostTensor::f32(
            vec![d_in, d_out],
            vec![0.5, -1.0, 2.0, 0.1, 1.5, 0.3, -0.7, 0.9, 0.2, -0.4, 0.8, 1.1],
        );
        let theta = [0.3f32, -0.8];
        let alpha = [1.1f32, 0.9];
        let mut r1 = vec![0f32; d_out];
        let mut r2 = vec![0f32; d_out];
        for k in 0..2 {
            let c = alpha[k] * theta[k].cos();
            let s = alpha[k] * theta[k].sin();
            r1[2 * k] = c;
            r1[2 * k + 1] = c;
            r2[2 * k] = s;
            r2[2 * k + 1] = s;
        }
        let x = [0.2f32, -0.5, 1.0];
        let wv = w.as_f32();
        let mut h = vec![0f32; d_out];
        for j in 0..d_out {
            for i in 0..d_in {
                h[j] += x[i] * wv[i * d_out + j];
            }
        }
        let want = road_rotate_vec(&h, &r1, &r2);
        let merged = road_merge_weight(&w, &r1, &r2);
        let mv = merged.as_f32();
        let mut got = vec![0f32; d_out];
        for j in 0..d_out {
            for i in 0..d_in {
                got[j] += x[i] * mv[i * d_out + j];
            }
        }
        for j in 0..d_out {
            assert!((got[j] - want[j]).abs() < 1e-5, "{got:?} vs {want:?}");
        }
    }

    #[test]
    fn lora_merge_rank1() {
        let w = HostTensor::f32(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let lb = vec![1.0, 2.0]; // [2,1]
        let la = vec![0.5, -0.5]; // [1,2]
        let m = lora_merge_weight(&w, &lb, &la, 1);
        assert_eq!(m.as_f32(), vec![1.5, -0.5, 1.0, 0.0]);
    }
}
