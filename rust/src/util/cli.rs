//! Tiny CLI argument parser (no clap in the offline image).
//!
//! Supports `command --flag value --flag=value positional` style. Parsing is
//! greedy: a bare `--flag` consumes the following token as its value when one
//! exists and is not itself a flag, so boolean flags should be written
//! `--flag=true`, placed last, or followed by another `--flag`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_flags_positional() {
        let a = Args::parse(&sv(&["serve", "--mode", "road", "--batch=8", "extra", "--verbose"]));
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get("mode"), Some("road"));
        assert_eq!(a.usize_or("batch", 1), 8);
        assert!(a.bool("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&["x"]));
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.f64_or("lr", 0.5), 0.5);
        assert!(!a.bool("missing"));
    }
}
