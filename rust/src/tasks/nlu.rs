//! The NLU suite: eight classification tasks standing in for GLUE
//! (Table 2).  Each mirrors the *kind* of reasoning its GLUE counterpart
//! needs — entailment-as-containment, paraphrase-as-permutation, graded
//! similarity, acceptability-as-grammar — over compact byte strings a
//! small transformer can learn in a few hundred steps.
//!
//! Every task formats as `"<tag>:<payload>>"` with a single label token as
//! the completion, so a single generative protocol covers the whole suite
//! (the prompt tag keeps tasks separable even when a shared backbone is
//! used for quick tests).

use super::{Example, Metric, Task};
use crate::util::rng::Rng;

const LETTERS: &[u8] = b"abcdefghijklmnop";

fn rand_str(rng: &mut Rng, n: usize, alphabet: &[u8]) -> String {
    (0..n).map(|_| alphabet[rng.below(alphabet.len())] as char).collect()
}

fn label_ex(tag: &str, payload: &str, label: usize) -> Example {
    let mut e = Example::gen(&format!("{tag}:{payload}>"), &label.to_string());
    e.answer = label;
    e
}

fn digit_tokens(k: usize) -> Vec<i32> {
    (0..k).map(|i| (b'0' + i as u8) as i32).collect()
}

/// RTE analogue: does the "hypothesis" (3 chars) occur as a contiguous
/// substring of the "premise" (8 chars)?
pub struct RteX;

impl Task for RteX {
    fn name(&self) -> &'static str {
        "rte-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn label_tokens(&self) -> Vec<i32> {
        digit_tokens(2)
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let premise = rand_str(rng, 8, &LETTERS[..8]);
        let (hyp, label) = if rng.chance(0.5) {
            let start = rng.below(6);
            (premise[start..start + 3].to_string(), 1)
        } else {
            // Random 3-gram, resampled until it's genuinely absent.
            loop {
                let h = rand_str(rng, 3, &LETTERS[..8]);
                if !premise.contains(&h) {
                    break (h, 0);
                }
            }
        };
        label_ex("R", &format!("{premise}|{hyp}"), label)
    }
}

/// MRPC analogue: is the second 6-char string a permutation (same
/// multiset) of the first?
pub struct MrpcX;

impl Task for MrpcX {
    fn name(&self) -> &'static str {
        "mrpc-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn label_tokens(&self) -> Vec<i32> {
        digit_tokens(2)
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let a: Vec<u8> = (0..6).map(|_| LETTERS[rng.below(6)]).collect();
        let mut b = a.clone();
        rng.shuffle(&mut b);
        let label = if rng.chance(0.5) {
            1
        } else {
            // Corrupt one position with a differing letter.
            let i = rng.below(6);
            let old = b[i];
            loop {
                let c = LETTERS[rng.below(6)];
                if c != old {
                    b[i] = c;
                    break;
                }
            }
            0
        };
        let a_s: String = a.iter().map(|&c| c as char).collect();
        let b_s: String = b.iter().map(|&c| c as char).collect();
        label_ex("M", &format!("{a_s}|{b_s}"), label)
    }
}

/// STS-B analogue: graded similarity 0..4 = quantized count of positions
/// where two 8-char strings agree.  Scored with Pearson correlation.
pub struct StsbX;

impl Task for StsbX {
    fn name(&self) -> &'static str {
        "stsb-x"
    }
    fn metric(&self) -> Metric {
        Metric::Pearson
    }
    fn label_tokens(&self) -> Vec<i32> {
        digit_tokens(5)
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let a: Vec<u8> = (0..8).map(|_| LETTERS[rng.below(4)]).collect();
        // Choose a target number of matches, then build b accordingly so
        // grades are uniform.
        let want = rng.below(5) * 2; // 0,2,4,6,8 matches
        let mut idx: Vec<usize> = (0..8).collect();
        rng.shuffle(&mut idx);
        let mut b = vec![0u8; 8];
        for (j, &i) in idx.iter().enumerate() {
            if j < want {
                b[i] = a[i];
            } else {
                loop {
                    let c = LETTERS[rng.below(4)];
                    if c != a[i] {
                        b[i] = c;
                        break;
                    }
                }
            }
        }
        let matches = a.iter().zip(&b).filter(|(x, y)| x == y).count();
        let grade = (matches / 2).min(4);
        let a_s: String = a.iter().map(|&c| c as char).collect();
        let b_s: String = b.iter().map(|&c| c as char).collect();
        label_ex("S", &format!("{a_s}|{b_s}"), grade)
    }
}

/// CoLA analogue: "acceptability" = membership in the regular language of
/// {a,b}-strings with no "bb" factor.  Scored with Matthew's correlation.
pub struct ColaX;

impl Task for ColaX {
    fn name(&self) -> &'static str {
        "cola-x"
    }
    fn metric(&self) -> Metric {
        Metric::Matthews
    }
    fn label_tokens(&self) -> Vec<i32> {
        digit_tokens(2)
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let n = 10;
        let mut s = Vec::with_capacity(n);
        if rng.chance(0.5) {
            // Valid walk: after 'b' always emit 'a'.
            let mut prev_b = false;
            for _ in 0..n {
                let c = if prev_b || rng.chance(0.6) { b'a' } else { b'b' };
                prev_b = c == b'b';
                s.push(c);
            }
            let txt: String = s.iter().map(|&c| c as char).collect();
            label_ex("C", &txt, 1)
        } else {
            // Inject at least one "bb".
            for _ in 0..n {
                s.push(if rng.chance(0.5) { b'a' } else { b'b' });
            }
            let i = rng.below(n - 1);
            s[i] = b'b';
            s[i + 1] = b'b';
            let txt: String = s.iter().map(|&c| c as char).collect();
            label_ex("C", &txt, 0)
        }
    }
}

/// SST-2 analogue: majority sentiment of a 10-token string drawn from a
/// positive lexicon {p,q,r,s}, a negative one {u,v,w,x} and neutral {m,n}.
pub struct Sst2X;

impl Task for Sst2X {
    fn name(&self) -> &'static str {
        "sst2-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn label_tokens(&self) -> Vec<i32> {
        digit_tokens(2)
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        loop {
            let mut pos = 0i32;
            let mut s = String::new();
            for _ in 0..10 {
                match rng.below(3) {
                    0 => {
                        s.push(b"pqrs"[rng.below(4)] as char);
                        pos += 1;
                    }
                    1 => {
                        s.push(b"uvwx"[rng.below(4)] as char);
                        pos -= 1;
                    }
                    _ => s.push(b"mn"[rng.below(2)] as char),
                }
            }
            if pos != 0 {
                return label_ex("T", &s, usize::from(pos > 0));
            }
        }
    }
}

/// QNLI analogue: does the query character occur in the 8-char context?
pub struct QnliX;

impl Task for QnliX {
    fn name(&self) -> &'static str {
        "qnli-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn label_tokens(&self) -> Vec<i32> {
        digit_tokens(2)
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let ctx = rand_str(rng, 8, &LETTERS[..10]);
        let (q, label) = if rng.chance(0.5) {
            (ctx.as_bytes()[rng.below(8)] as char, 1)
        } else {
            loop {
                let c = LETTERS[rng.below(10)] as char;
                if !ctx.contains(c) {
                    break (c, 0);
                }
            }
        };
        label_ex("Q", &format!("{q}|{ctx}"), label)
    }
}

/// QQP analogue: "duplicate questions" = equal 6-char strings up to sorted
/// order over a small alphabet (duplicates allowed), with hard negatives
/// that differ in exactly one slot.
pub struct QqpX;

impl Task for QqpX {
    fn name(&self) -> &'static str {
        "qqp-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn label_tokens(&self) -> Vec<i32> {
        digit_tokens(2)
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let mut a: Vec<u8> = (0..6).map(|_| LETTERS[rng.below(4)]).collect();
        let mut b = a.clone();
        rng.shuffle(&mut b);
        let label = if rng.chance(0.5) {
            1
        } else {
            let i = rng.below(6);
            let old = b[i];
            loop {
                let c = LETTERS[rng.below(4)];
                if c != old {
                    b[i] = c;
                    break;
                }
            }
            0
        };
        rng.shuffle(&mut a);
        let a_s: String = a.iter().map(|&c| c as char).collect();
        let b_s: String = b.iter().map(|&c| c as char).collect();
        label_ex("P", &format!("{a_s}|{b_s}"), label)
    }
}

/// MNLI analogue (3-way): hypothesis chars all inside the premise
/// (entailment=0), all outside (contradiction=1), or mixed (neutral=2).
pub struct MnliX;

impl Task for MnliX {
    fn name(&self) -> &'static str {
        "mnli-x"
    }
    fn metric(&self) -> Metric {
        Metric::Accuracy
    }
    fn label_tokens(&self) -> Vec<i32> {
        digit_tokens(3)
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let prem: Vec<u8> = {
            // 6 distinct letters from the first 12.
            let mut pool: Vec<u8> = LETTERS[..12].to_vec();
            rng.shuffle(&mut pool);
            pool.truncate(6);
            pool
        };
        let outside: Vec<u8> =
            LETTERS[..12].iter().copied().filter(|c| !prem.contains(c)).collect();
        let label = rng.below(3);
        let hyp: Vec<u8> = match label {
            0 => (0..3).map(|_| prem[rng.below(6)]).collect(),
            1 => (0..3).map(|_| outside[rng.below(outside.len())]).collect(),
            _ => {
                vec![
                    prem[rng.below(6)],
                    outside[rng.below(outside.len())],
                    if rng.chance(0.5) {
                        prem[rng.below(6)]
                    } else {
                        outside[rng.below(outside.len())]
                    },
                ]
            }
        };
        let p: String = prem.iter().map(|&c| c as char).collect();
        let h: String = hyp.iter().map(|&c| c as char).collect();
        label_ex("N", &format!("{p}|{h}"), label)
    }
}

/// The eight NLU tasks in Table-2 column order.
pub fn all() -> Vec<Box<dyn Task>> {
    vec![
        Box::new(RteX),
        Box::new(MrpcX),
        Box::new(StsbX),
        Box::new(ColaX),
        Box::new(Sst2X),
        Box::new(QnliX),
        Box::new(QqpX),
        Box::new(MnliX),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_balance(task: &dyn Task, n_classes: usize) {
        let mut rng = Rng::seed_from(99);
        let mut counts = vec![0usize; n_classes];
        for _ in 0..600 {
            let ex = task.sample(&mut rng);
            assert!(ex.answer < n_classes, "{}", task.name());
            counts[ex.answer] += 1;
        }
        for (c, &n) in counts.iter().enumerate() {
            assert!(
                n > 600 / n_classes / 3,
                "{} class {c} underrepresented: {counts:?}",
                task.name()
            );
        }
    }

    #[test]
    fn labels_are_balanced() {
        check_balance(&RteX, 2);
        check_balance(&MrpcX, 2);
        check_balance(&StsbX, 5);
        check_balance(&ColaX, 2);
        check_balance(&Sst2X, 2);
        check_balance(&QnliX, 2);
        check_balance(&QqpX, 2);
        check_balance(&MnliX, 3);
    }

    #[test]
    fn rte_positive_is_substring() {
        let mut rng = Rng::seed_from(5);
        for _ in 0..200 {
            let ex = RteX.sample(&mut rng);
            let txt = crate::tokenizer::decode(&ex.prompt);
            let body = txt.trim_start_matches("R:").trim_end_matches('>');
            let (p, h) = body.split_once('|').unwrap();
            assert_eq!(p.contains(h), ex.answer == 1, "{txt}");
        }
    }

    #[test]
    fn mrpc_positive_is_permutation() {
        let mut rng = Rng::seed_from(6);
        for _ in 0..200 {
            let ex = MrpcX.sample(&mut rng);
            let txt = crate::tokenizer::decode(&ex.prompt);
            let body = txt.trim_start_matches("M:").trim_end_matches('>');
            let (a, b) = body.split_once('|').unwrap();
            let mut av: Vec<char> = a.chars().collect();
            let mut bv: Vec<char> = b.chars().collect();
            av.sort();
            bv.sort();
            assert_eq!(av == bv, ex.answer == 1, "{txt}");
        }
    }

    #[test]
    fn cola_label_matches_grammar() {
        let mut rng = Rng::seed_from(8);
        for _ in 0..200 {
            let ex = ColaX.sample(&mut rng);
            let txt = crate::tokenizer::decode(&ex.prompt);
            let body = txt.trim_start_matches("C:").trim_end_matches('>');
            assert_eq!(!body.contains("bb"), ex.answer == 1, "{txt}");
        }
    }

    #[test]
    fn stsb_grade_matches_overlap() {
        let mut rng = Rng::seed_from(4);
        for _ in 0..200 {
            let ex = StsbX.sample(&mut rng);
            let txt = crate::tokenizer::decode(&ex.prompt);
            let body = txt.trim_start_matches("S:").trim_end_matches('>');
            let (a, b) = body.split_once('|').unwrap();
            let m = a.chars().zip(b.chars()).filter(|(x, y)| x == y).count();
            assert_eq!((m / 2).min(4), ex.answer, "{txt}");
        }
    }

    #[test]
    fn label_completion_is_digit() {
        let mut rng = Rng::seed_from(3);
        for t in all() {
            let ex = t.sample(&mut rng);
            assert_eq!(ex.completion.len(), 1);
            let tok = ex.completion[0];
            assert!(t.label_tokens().contains(&tok), "{}", t.name());
            assert_eq!(tok, (b'0' + ex.answer as u8) as i32);
        }
    }
}
