//! Pure-Rust reference backend: a deterministic tiny-transformer forward
//! pass that satisfies the same entrypoint contract as the PJRT artifacts.
//!
//! The PJRT path executes HLO lowered from python/compile/model.py; this
//! module reimplements that graph's *serving* entries (`prefill_*` /
//! `decode_*`) directly in Rust — embedding, RoPE attention over the
//! per-slot KV cache, SwiGLU MLP, and the banked per-request adapter
//! epilogues (RoAd Eq. 4 element-wise rotation, the LoRA bmm baseline,
//! (IA)³ scaling) — so the whole engine/streaming/scheduling stack runs
//! end to end with **no artifacts and no native XLA runtime**.
//!
//! Contract (docs/DESIGN.md §Backends):
//!
//! * Entry names, input/output signatures, group conventions
//!   (`params`/`adapters`/`data`), and shapes are identical to what
//!   python/compile/aot.py records in the manifest.  The engine cannot
//!   tell the backends apart.
//! * The math mirrors model.py line for line (same masks, same cache
//!   scatter semantics, same RoPE tables), so when artifacts *are* built
//!   the two backends agree to greedy-token identity on the same weights
//!   (the cross-backend test in rust/tests/integration_engine.rs).
//! * Every lane is computed independently, so a request's output is
//!   bitwise identical whether it runs solo or inside a heterogeneous
//!   batch — the batch-invariance the paper's batching claim rests on,
//!   and the property the un-gated integration suite asserts.
//!
//! Without artifacts, [`synthetic_manifest`] supplies the entry/config
//! metadata and [`synthetic_params`] deterministically generates the
//! "pretrained" weights (seeded per config name), so two processes always
//! serve the same model.

use std::borrow::Cow;
use std::cell::Cell;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use super::epilogue::{self, BankView};
use crate::manifest::{EntryInfo, IoSpec, Manifest, ModelConfigInfo};
use crate::model::{proj_dims, PROJS};
use crate::tensor::{DType, HostTensor};
use crate::util::rng::Rng;

/// RoPE base used by every preset (python/compile/configs.py
/// `ModelConfig.rope_theta` default; the manifest does not carry it).
pub const ROPE_THETA: f32 = 10000.0;

/// Adapter modes the reference backend implements (model.py also lowers
/// "oft", which exists only as a baseline for the training-efficiency
/// table and stays PJRT-only).
pub const MODES: [&str; 4] = ["base", "road", "lora", "ia3"];

// ---------------------------------------------------------------------------
// Synthetic manifest (configs + serving entries, no files behind them)
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn cfg(
    name: &str,
    vocab: usize,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    d_ff: usize,
    max_seq: usize,
    n_adapters: usize,
    lora_rank: usize,
) -> ModelConfigInfo {
    ModelConfigInfo {
        name: name.into(),
        vocab,
        d_model,
        n_layers,
        n_heads,
        d_ff,
        max_seq,
        head_dim: d_model / n_heads,
        n_adapters,
        lora_rank,
    }
}

/// The four presets, mirroring python/compile/configs.py exactly.
pub fn synthetic_configs() -> BTreeMap<String, ModelConfigInfo> {
    let mut m = BTreeMap::new();
    for c in [
        cfg("tiny", 256, 64, 2, 4, 192, 128, 4, 4),
        cfg("serve", 256, 256, 4, 8, 768, 288, 16, 8),
        cfg("train", 256, 128, 3, 4, 384, 96, 4, 8),
        cfg("train2", 256, 96, 4, 6, 288, 96, 4, 8),
    ] {
        m.insert(c.name.clone(), c);
    }
    m
}

/// Parameter (name, shape) specs in flattening order (sorted keys) —
/// python/compile/model.py `param_specs`.
pub fn param_spec_list(cfg: &ModelConfigInfo) -> Vec<(String, Vec<usize>)> {
    let mut m: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    m.insert("tok_emb".into(), vec![cfg.vocab, cfg.d_model]);
    m.insert("final_norm".into(), vec![cfg.d_model]);
    m.insert("lm_head".into(), vec![cfg.d_model, cfg.vocab]);
    for i in 0..cfg.n_layers {
        let pre = format!("blocks.{i}");
        m.insert(format!("{pre}.attn_norm"), vec![cfg.d_model]);
        m.insert(format!("{pre}.ffn_norm"), vec![cfg.d_model]);
        for proj in PROJS {
            let (d_in, d_out) = proj_dims(cfg, proj);
            m.insert(format!("{pre}.{proj}"), vec![d_in, d_out]);
            m.insert(format!("{pre}.{proj}.bias"), vec![d_out]);
        }
    }
    m.into_iter().collect()
}

/// Adapter-bank (name, shape) specs in sorted order — python
/// `adapter_specs` for the serving modes.
pub fn adapter_spec_list(cfg: &ModelConfigInfo, mode: &str) -> Vec<(String, Vec<usize>)> {
    let n = cfg.n_adapters;
    let mut m: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for i in 0..cfg.n_layers {
        for proj in PROJS {
            let (d_in, d_out) = proj_dims(cfg, proj);
            let key = format!("blocks.{i}.{proj}");
            match mode {
                "road" => {
                    m.insert(format!("{key}.r1"), vec![n, d_out]);
                    m.insert(format!("{key}.r2"), vec![n, d_out]);
                }
                "lora" => {
                    m.insert(format!("{key}.lb"), vec![n, d_in, cfg.lora_rank]);
                    m.insert(format!("{key}.la"), vec![n, cfg.lora_rank, d_out]);
                }
                "ia3" => {
                    m.insert(format!("{key}.s"), vec![n, d_out]);
                }
                _ => {}
            }
        }
    }
    m.into_iter().collect()
}

fn iospec(group: &str, name: &str, shape: Vec<usize>, dtype: DType) -> IoSpec {
    IoSpec { group: group.into(), name: name.into(), shape, dtype }
}

/// Build the EntryInfo for one serving entry, positional order identical
/// to aot.py's `serving_entry` (params, adapters, data).
fn serving_entry(cfg: &ModelConfigInfo, mode: &str, kind: &str, b: usize, l: usize) -> EntryInfo {
    let mut inputs: Vec<IoSpec> = param_spec_list(cfg)
        .into_iter()
        .map(|(n, s)| iospec("params", &n, s, DType::F32))
        .collect();
    inputs.extend(
        adapter_spec_list(cfg, mode)
            .into_iter()
            .map(|(n, s)| iospec("adapters", &n, s, DType::F32)),
    );
    let (nl, h, t, hd) = (cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim);
    let cache_shape = vec![nl, b, h, t, hd];
    let (name, prompt_len) = if kind == "prefill" {
        inputs.push(iospec("data", "ids", vec![b], DType::I32));
        inputs.push(iospec("data", "tokens", vec![b, l], DType::I32));
        inputs.push(iospec("data", "lengths", vec![b], DType::I32));
        (format!("prefill_{mode}_{}_b{b}_l{l}", cfg.name), Some(l))
    } else if kind == "chunk_prefill" {
        // Mixed-step chunked prefill: continue each lane's existing cache
        // by `len[lane]` prompt tokens written at absolute positions
        // `start[lane]..`; `tokens` is [b, max_seq] so a chunk lands at
        // its true positions without per-chunk shapes.  Lanes with
        // len == 0 are untouched.
        inputs.push(iospec("data", "ids", vec![b], DType::I32));
        inputs.push(iospec("data", "tokens", vec![b, t], DType::I32));
        inputs.push(iospec("data", "start", vec![b], DType::I32));
        inputs.push(iospec("data", "len", vec![b], DType::I32));
        inputs.push(iospec("data", "k_cache", cache_shape.clone(), DType::F32));
        inputs.push(iospec("data", "v_cache", cache_shape.clone(), DType::F32));
        (format!("chunk_prefill_{mode}_{}_b{b}", cfg.name), None)
    } else {
        inputs.push(iospec("data", "ids", vec![b], DType::I32));
        inputs.push(iospec("data", "token", vec![b], DType::I32));
        inputs.push(iospec("data", "pos", vec![b], DType::I32));
        inputs.push(iospec("data", "k_cache", cache_shape.clone(), DType::F32));
        inputs.push(iospec("data", "v_cache", cache_shape.clone(), DType::F32));
        (format!("decode_{mode}_{}_b{b}", cfg.name), None)
    };
    let outputs = vec![
        iospec("out", "out0", vec![b, cfg.vocab], DType::F32),
        iospec("out", "out1", cache_shape.clone(), DType::F32),
        iospec("out", "out2", cache_shape, DType::F32),
    ];
    EntryInfo {
        name,
        file: String::new(),
        kind: kind.into(),
        config: cfg.name.clone(),
        mode: Some(mode.into()),
        method: None,
        batch: Some(b),
        prompt_len,
        seq_len: None,
        inputs,
        outputs,
    }
}

/// Decode-slot counts every config gets entries for (superset of aot.py's
/// `SERVE_DECODE_BATCHES` — synthesizing an entry costs nothing, so the
/// reference backend is more generous than the compiled artifact set).
pub const DECODE_BATCHES: [usize; 5] = [1, 2, 4, 8, 16];

/// Prefill (batch, prompt_len) buckets per config.
pub const PREFILL_BUCKETS: [(usize, usize); 4] = [(1, 16), (2, 16), (4, 16), (8, 16)];

/// Prefill buckets a config's entries are synthesized for: the shared
/// list plus the long-prompt serve bucket, filtered to `max_seq`.  Also
/// the source of truth for the manifest's advertised `serve_prefill`
/// buckets, so the bucket metadata can never contradict the entry set.
pub fn prefill_buckets_for(cfg: &ModelConfigInfo) -> Vec<(usize, usize)> {
    let mut buckets: Vec<(usize, usize)> = PREFILL_BUCKETS.to_vec();
    if cfg.name == "serve" {
        buckets.push((8, 64));
    }
    if cfg.name == "tiny" {
        // A long-prompt bucket for the cheap test config, so scheduler
        // tests can admit prompts past the 16-token buckets without the
        // ~250× heavier "serve" forward pass.
        buckets.push((2, 32));
    }
    buckets.retain(|&(_, l)| l <= cfg.max_seq);
    buckets
}

/// In-memory manifest for the reference backend: same configs, entry
/// names, and signatures as `make artifacts` would produce, but no files
/// behind them and `synthetic = true` (parameters come from
/// [`synthetic_params`]).
pub fn synthetic_manifest() -> Manifest {
    let configs = synthetic_configs();
    let mut entries = BTreeMap::new();
    for c in configs.values() {
        for mode in MODES {
            for b in DECODE_BATCHES {
                let e = serving_entry(c, mode, "decode", b, 0);
                entries.insert(e.name.clone(), e);
                let e = serving_entry(c, mode, "chunk_prefill", b, 0);
                entries.insert(e.name.clone(), e);
            }
            for (b, l) in prefill_buckets_for(c) {
                let e = serving_entry(c, mode, "prefill", b, l);
                entries.insert(e.name.clone(), e);
            }
        }
    }
    let serve_prefill_buckets = prefill_buckets_for(&configs["serve"]);
    Manifest {
        dir: PathBuf::from("<reference>"),
        configs,
        entries,
        params_files: BTreeMap::new(),
        trainable_files: BTreeMap::new(),
        golden: BTreeMap::new(),
        serve_decode_batches: DECODE_BATCHES.to_vec(),
        serve_prefill_buckets,
        synthetic: true,
    }
}

/// The identity row content for one adapter-bank input spec: ones for
/// multiplicative tensors (road `.r1`, ia3 `.s`), zeros for additive ones
/// (road `.r2`, lora `.lb`/`.la`) — matching [`crate::adapters::AdapterBank`]'s
/// fresh-bank initialization.  Shared by the reference/runtime tests that
/// assemble positional inputs by hand.
pub fn identity_bank_tensor(spec: &IoSpec) -> HostTensor {
    let n: usize = spec.shape.iter().product::<usize>().max(1);
    if spec.name.ends_with(".r1") || spec.name.ends_with(".s") {
        HostTensor::f32(spec.shape.clone(), vec![1.0; n])
    } else {
        HostTensor::zeros(spec.shape.clone(), DType::F32)
    }
}

/// Deterministic "pretrained" parameters for a synthetic config: same
/// structure and init scales as python `init_params` (normal·d⁻½ weights,
/// unit norms, zero biases), seeded from the config name so every process
/// serves the same model.
pub fn synthetic_params(
    cfg: &ModelConfigInfo,
    specs: &[(String, Vec<usize>)],
) -> Vec<(String, HostTensor)> {
    let seed = cfg
        .name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3));
    let mut rng = Rng::seed_from(seed);
    let emb_scale = (cfg.d_model as f32).powf(-0.5);
    specs
        .iter()
        .map(|(name, shape)| {
            let n: usize = shape.iter().product::<usize>().max(1);
            let vals = if name.ends_with(".bias") {
                vec![0.0; n]
            } else if name.ends_with("_norm") {
                vec![1.0; n]
            } else if name == "tok_emb" || name == "lm_head" {
                rng.normal_vec(n, emb_scale)
            } else {
                // Projection weights: scale by the input dimension.
                let d_in = shape[0] as f32;
                rng.normal_vec(n, d_in.powf(-0.5))
            };
            (name.clone(), HostTensor::f32(shape.clone(), vals))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Reference executable: one parsed serving entry
// ---------------------------------------------------------------------------

/// What one reference entry computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RefKind {
    Prefill,
    ChunkPrefill,
    Decode,
}

/// A reference-backend "executable": the parsed serving entry plus its
/// model config.  Stateless — all tensors arrive as call arguments, the
/// same way a compiled PJRT executable receives them.
pub struct RefEntry {
    info: EntryInfo,
    cfg: ModelConfigInfo,
    kind: RefKind,
    mode: String,
    /// Epilogue path selector shared with the owning [`super::Runtime`]
    /// ([`RefEntry::attach_fused`]): fused chunked kernel when true, the
    /// scalar oracle when false.
    fused: Rc<Cell<bool>>,
}

impl RefEntry {
    /// Parse a manifest entry into a runnable reference entry.  Only the
    /// serving kinds exist here; training/eval/pilot entries stay
    /// PJRT-only and fail loudly.
    pub fn from_info(info: &EntryInfo, cfg: &ModelConfigInfo) -> Result<RefEntry> {
        let kind = match info.kind.as_str() {
            "prefill" => RefKind::Prefill,
            "chunk_prefill" => RefKind::ChunkPrefill,
            "decode" => RefKind::Decode,
            k => bail!(
                "reference backend implements serving entries only \
                 (prefill/chunk_prefill/decode); \
                 {} is kind {k:?} — use the pjrt backend with built artifacts",
                info.name
            ),
        };
        let mode = info.mode.clone().unwrap_or_default();
        if !MODES.contains(&mode.as_str()) {
            bail!("reference backend does not implement adapter mode {mode:?} ({})", info.name);
        }
        // RoAd rotates element *pairs*: an odd projection width would
        // silently leave the last element unrotated, so it is rejected
        // here — at entry construction — not discovered mid-decode.
        if mode == "road" {
            for proj in PROJS {
                let (_, d_out) = proj_dims(cfg, proj);
                if d_out % 2 != 0 {
                    bail!(
                        "config {}: road mode needs even projection widths, {proj} has d_out \
                         {d_out} ({})",
                        cfg.name,
                        info.name
                    );
                }
            }
        }
        Ok(RefEntry {
            info: info.clone(),
            cfg: cfg.clone(),
            kind,
            mode,
            fused: Rc::new(Cell::new(true)),
        })
    }

    /// Share the runtime's epilogue selector with this entry (called by
    /// [`super::Runtime::load`]; a standalone `from_info` keeps its own
    /// cell, defaulting to fused).
    pub fn attach_fused(&mut self, fused: Rc<Cell<bool>>) {
        self.fused = fused;
    }

    /// Execute the entry on host tensors in positional signature order.
    pub fn execute(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.info.inputs.len() {
            bail!(
                "entry {}: {} args provided, {} expected",
                self.info.name,
                inputs.len(),
                self.info.inputs.len()
            );
        }
        let mut params: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        let mut adapters: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        let mut data: BTreeMap<&str, &HostTensor> = BTreeMap::new();
        for (spec, t) in self.info.inputs.iter().zip(inputs) {
            match spec.group.as_str() {
                "params" => params.insert(spec.name.as_str(), t),
                "adapters" => adapters.insert(spec.name.as_str(), t),
                "data" => data.insert(spec.name.as_str(), t),
                g => bail!("entry {}: unexpected input group {g}", self.info.name),
            };
        }
        let fwd = Fwd {
            cfg: &self.cfg,
            mode: &self.mode,
            params: &params,
            adapters: &adapters,
            fused: self.fused.get(),
        };
        let datum = |name: &str| {
            data.get(name)
                .copied()
                .ok_or_else(|| anyhow!("entry {}: missing data input {name}", self.info.name))
        };
        match self.kind {
            RefKind::Prefill => {
                let b = self.info.batch.unwrap_or(1);
                let l = self.info.prompt_len.unwrap_or(0);
                fwd.prefill(
                    b,
                    l,
                    &datum("ids")?.as_i32(),
                    &datum("tokens")?.as_i32(),
                    &datum("lengths")?.as_i32(),
                )
            }
            RefKind::ChunkPrefill => {
                let b = self.info.batch.unwrap_or(1);
                fwd.chunk_prefill(
                    b,
                    &datum("ids")?.as_i32(),
                    &datum("tokens")?.as_i32(),
                    &datum("start")?.as_i32(),
                    &datum("len")?.as_i32(),
                    datum("k_cache")?,
                    datum("v_cache")?,
                )
            }
            RefKind::Decode => {
                let b = self.info.batch.unwrap_or(1);
                fwd.decode(
                    b,
                    &datum("ids")?.as_i32(),
                    &datum("token")?.as_i32(),
                    &datum("pos")?.as_i32(),
                    datum("k_cache")?,
                    datum("v_cache")?,
                )
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Forward math (mirrors python/compile/model.py)
// ---------------------------------------------------------------------------

/// Borrow a tensor's payload as f32 without copying when aligned.
fn f32s(t: &HostTensor) -> Cow<'_, [f32]> {
    match t.f32_slice() {
        Some(s) => Cow::Borrowed(s),
        None => Cow::Owned(t.as_f32()),
    }
}

fn rmsnorm_rows(x: &[f32], rows: usize, d: usize, g: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; rows * d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut ss = 0f32;
        for v in xr {
            ss += v * v;
        }
        let inv = 1.0 / (ss / d as f32 + 1e-5).sqrt();
        let or = &mut out[r * d..(r + 1) * d];
        for i in 0..d {
            or[i] = xr[i] * inv * g[i];
        }
    }
    out
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

struct Fwd<'a> {
    cfg: &'a ModelConfigInfo,
    mode: &'a str,
    params: &'a BTreeMap<&'a str, &'a HostTensor>,
    adapters: &'a BTreeMap<&'a str, &'a HostTensor>,
    /// Fused chunked epilogue kernels vs the scalar oracle
    /// ([`crate::runtime::epilogue`]); both produce identical bits.
    fused: bool,
}

impl Fwd<'_> {
    fn p(&self, name: &str) -> Result<Cow<'_, [f32]>> {
        self.params.get(name).copied().map(f32s).ok_or_else(|| anyhow!("missing param {name}"))
    }

    fn a(&self, name: &str) -> Result<Cow<'_, [f32]>> {
        self.adapters
            .get(name)
            .copied()
            .map(f32s)
            .ok_or_else(|| anyhow!("missing adapter bank {name}"))
    }

    /// Adapted linear layer over `rows` row-vectors: z = x W + b, then the
    /// per-row adapter epilogue selected by `mode` with bank slot
    /// `slots[row]` (model.py `_linear`).
    fn linear(
        &self,
        key: &str,
        x: &[f32],
        rows: usize,
        slots: &[usize],
        d_in: usize,
        d_out: usize,
    ) -> Result<Vec<f32>> {
        let w = self.p(key)?;
        let bias = self.p(&format!("{key}.bias"))?;
        let mut z = vec![0f32; rows * d_out];
        for r in 0..rows {
            let xr = &x[r * d_in..(r + 1) * d_in];
            let zr = &mut z[r * d_out..(r + 1) * d_out];
            zr.copy_from_slice(&bias);
            // No `xv == 0.0` shortcut: 0·NaN / 0·inf must propagate (IEEE
            // semantics, and PJRT agreement), and timing must not depend
            // on activation sparsity.
            for (i, &xv) in xr.iter().enumerate() {
                let wrow = &w[i * d_out..(i + 1) * d_out];
                for j in 0..d_out {
                    zr[j] += xv * wrow[j];
                }
            }
        }
        match self.mode {
            "base" => Ok(z),
            "road" => {
                // Eq. 4: z' = r1 ⊙ z + r2 ⊙ pairswap(z), adapter chosen by
                // the row's bank slot (a gather of two vectors).
                let (k1, k2) = (format!("{key}.r1"), format!("{key}.r2"));
                let (r1, r2) = (self.a(&k1)?, self.a(&k2)?);
                let r1v = BankView::new(&k1, &r1, d_out)?;
                let r2v = BankView::new(&k2, &r2, d_out)?;
                epilogue::road(&mut z, d_out, slots, &r1v, &r2v, self.fused)?;
                Ok(z)
            }
            "lora" => {
                // z' = z + (x B) A — the bmm-chain baseline of Figure 4.
                let (kb, ka) = (format!("{key}.lb"), format!("{key}.la"));
                let (lb, la) = (self.a(&kb)?, self.a(&ka)?);
                let rank = self.cfg.lora_rank;
                let lbv = BankView::new(&kb, &lb, d_in * rank)?;
                let lav = BankView::new(&ka, &la, rank * d_out)?;
                epilogue::lora(&mut z, x, d_in, d_out, rank, slots, &lbv, &lav, self.fused)?;
                Ok(z)
            }
            "ia3" => {
                let ks = format!("{key}.s");
                let sb = self.a(&ks)?;
                let sv = BankView::new(&ks, &sb, d_out)?;
                epilogue::ia3(&mut z, d_out, slots, &sv, self.fused)?;
                Ok(z)
            }
            m => bail!("reference backend: unsupported mode {m}"),
        }
    }

    /// Apply RoPE in place to `q` rows laid out [rows, n_heads*head_dim],
    /// one position per row (model.py `apply_rope`).  The inverse-frequency
    /// table depends only on `k` and the angle only on `(row, k)`, so both
    /// are hoisted out of the head loop (python's `rope_tables` shape).
    fn rope(&self, x: &mut [f32], rows: usize, pos: &[usize]) {
        let (h, hd) = (self.cfg.n_heads, self.cfg.head_dim);
        let half = hd / 2;
        let inv: Vec<f32> =
            (0..half).map(|k| ROPE_THETA.powf(-((2 * k) as f32) / hd as f32)).collect();
        for r in 0..rows {
            let p = pos[r] as f32;
            for (k, &ik) in inv.iter().enumerate() {
                let ang = p * ik;
                let (c, s) = (ang.cos(), ang.sin());
                for hh in 0..h {
                    let off = r * h * hd + hh * hd;
                    let (e, o) = (off + 2 * k, off + 2 * k + 1);
                    let (x1, x2) = (x[e], x[o]);
                    x[e] = x1 * c - x2 * s;
                    x[o] = x1 * s + x2 * c;
                }
            }
        }
    }

    /// One transformer block over `rows = b*l` row-vectors, updating this
    /// layer's caches in place (model.py `_block`).
    ///
    /// `kc`/`vc` are this layer's [b, h, T, hd] cache slices; `write_pos`
    /// gives the cache position each row's K/V lands in, and `visible`
    /// says which cache positions a row's query may attend.
    #[allow(clippy::too_many_arguments)]
    fn block(
        &self,
        layer: usize,
        x: &mut Vec<f32>,
        b: usize,
        l: usize,
        slots: &[usize],
        rope_pos: &[usize],
        kc: &mut [f32],
        vc: &mut [f32],
        write_pos: &[usize],
        visible: &dyn Fn(usize, usize) -> bool,
    ) -> Result<()> {
        let (d, h, hd) = (self.cfg.d_model, self.cfg.n_heads, self.cfg.head_dim);
        let t_max = self.cfg.max_seq;
        let rows = b * l;
        let pre = format!("blocks.{layer}");
        let lin = |nm: &str, inp: &[f32], d_in: usize, d_out: usize| {
            self.linear(&format!("{pre}.{nm}"), inp, rows, slots, d_in, d_out)
        };

        let hn = rmsnorm_rows(x, rows, d, &self.p(&format!("{pre}.attn_norm"))?);
        let mut q = lin("wq", &hn, d, d)?;
        let mut k = lin("wk", &hn, d, d)?;
        let v = lin("wv", &hn, d, d)?;
        self.rope(&mut q, rows, rope_pos);
        self.rope(&mut k, rows, rope_pos);

        // Scatter this call's K/V into the cache at each row's write
        // position (the one-hot blend of model.py, done as direct writes —
        // write positions are distinct per lane by construction).
        for r in 0..rows {
            let (lane, p) = (r / l, write_pos[r]);
            for hh in 0..h {
                let src = r * h * hd + hh * hd;
                let dst = ((lane * h + hh) * t_max + p) * hd;
                kc[dst..dst + hd].copy_from_slice(&k[src..src + hd]);
                vc[dst..dst + hd].copy_from_slice(&v[src..src + hd]);
            }
        }

        // Attention over the (just-updated) cache.
        let scale = (hd as f32).powf(-0.5);
        let mut ctx = vec![0f32; rows * d];
        let mut scores = vec![0f32; t_max];
        for r in 0..rows {
            let lane = r / l;
            for hh in 0..h {
                let qoff = r * h * hd + hh * hd;
                let qrow = &q[qoff..qoff + hd];
                let base = (lane * h + hh) * t_max * hd;
                let mut max = f32::NEG_INFINITY;
                for (t, sc) in scores.iter_mut().enumerate() {
                    if !visible(r, t) {
                        *sc = f32::NEG_INFINITY;
                        continue;
                    }
                    let krow = &kc[base + t * hd..base + (t + 1) * hd];
                    let mut dot = 0f32;
                    for i in 0..hd {
                        dot += qrow[i] * krow[i];
                    }
                    *sc = dot * scale;
                    if *sc > max {
                        max = *sc;
                    }
                }
                let mut denom = 0f32;
                for sc in scores.iter_mut() {
                    *sc = if sc.is_finite() { (*sc - max).exp() } else { 0.0 };
                    denom += *sc;
                }
                let co = r * d + hh * hd;
                let crow = &mut ctx[co..co + hd];
                for (t, &w) in scores.iter().enumerate() {
                    if w == 0.0 {
                        continue;
                    }
                    let wv = w / denom;
                    let vrow = &vc[base + t * hd..base + (t + 1) * hd];
                    for i in 0..hd {
                        crow[i] += wv * vrow[i];
                    }
                }
            }
        }
        let attn_out = lin("wo", &ctx, d, d)?;
        for (xi, ai) in x.iter_mut().zip(&attn_out) {
            *xi += ai;
        }

        // SwiGLU MLP.
        let h2 = rmsnorm_rows(x, rows, d, &self.p(&format!("{pre}.ffn_norm"))?);
        let gate = lin("wgate", &h2, d, self.cfg.d_ff)?;
        let up = lin("wup", &h2, d, self.cfg.d_ff)?;
        let act: Vec<f32> = gate.iter().zip(&up).map(|(&g, &u)| silu(g) * u).collect();
        let down = lin("wdown", &act, self.cfg.d_ff, d)?;
        for (xi, di) in x.iter_mut().zip(&down) {
            *xi += di;
        }
        Ok(())
    }

    fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let emb = self.p("tok_emb")?;
        let (v, d) = (self.cfg.vocab, self.cfg.d_model);
        let mut x = vec![0f32; tokens.len() * d];
        for (r, &tok) in tokens.iter().enumerate() {
            let idx = (tok.max(0) as usize).min(v - 1);
            x[r * d..(r + 1) * d].copy_from_slice(&emb[idx * d..(idx + 1) * d]);
        }
        Ok(x)
    }

    /// Final-norm + lm_head logits for one row of `x`.
    fn head_row(&self, x: &[f32], row: usize) -> Result<Vec<f32>> {
        let d = self.cfg.d_model;
        let hn = rmsnorm_rows(&x[row * d..(row + 1) * d], 1, d, &self.p("final_norm")?);
        let lm = self.p("lm_head")?;
        let v = self.cfg.vocab;
        let mut logits = vec![0f32; v];
        for (i, &hv) in hn.iter().enumerate() {
            if hv == 0.0 {
                continue;
            }
            let wrow = &lm[i * v..(i + 1) * v];
            for j in 0..v {
                logits[j] += hv * wrow[j];
            }
        }
        Ok(logits)
    }

    fn cache_shape(&self, b: usize) -> Vec<usize> {
        vec![self.cfg.n_layers, b, self.cfg.n_heads, self.cfg.max_seq, self.cfg.head_dim]
    }

    /// model.py `prefill`: process padded prompts, fill the caches, return
    /// last-valid-token logits.
    fn prefill(
        &self,
        b: usize,
        l: usize,
        ids: &[i32],
        tokens: &[i32],
        lengths: &[i32],
    ) -> Result<Vec<HostTensor>> {
        let cfg = self.cfg;
        let rows = b * l;
        let slots: Vec<usize> = (0..rows).map(|r| ids[r / l].max(0) as usize).collect();
        let rope_pos: Vec<usize> = (0..rows).map(|r| r % l).collect();
        let write_pos = rope_pos.clone();
        let mut x = self.embed(tokens)?;

        let lane_cache = b * cfg.n_heads * cfg.max_seq * cfg.head_dim;
        let mut kcs = vec![0f32; cfg.n_layers * lane_cache];
        let mut vcs = vec![0f32; cfg.n_layers * lane_cache];
        // Query j attends cache positions t <= j that prefill wrote (t < l).
        let visible = move |r: usize, t: usize| t <= (r % l) && t < l;
        for layer in 0..cfg.n_layers {
            let (kc, vc) = (
                &mut kcs[layer * lane_cache..(layer + 1) * lane_cache],
                &mut vcs[layer * lane_cache..(layer + 1) * lane_cache],
            );
            self.block(layer, &mut x, b, l, &slots, &rope_pos, kc, vc, &write_pos, &visible)?;
        }
        let mut logits = vec![0f32; b * cfg.vocab];
        for lane in 0..b {
            let last = (lengths[lane] - 1).clamp(0, l as i32 - 1) as usize;
            let row = self.head_row(&x, lane * l + last)?;
            logits[lane * cfg.vocab..(lane + 1) * cfg.vocab].copy_from_slice(&row);
        }
        Ok(vec![
            HostTensor::f32(vec![b, cfg.vocab], logits),
            HostTensor::f32(self.cache_shape(b), kcs),
            HostTensor::f32(self.cache_shape(b), vcs),
        ])
    }

    /// Chunked prefill: continue each granted lane's cache by `len[lane]`
    /// prompt tokens written at absolute positions `start[lane]..`,
    /// reusing whatever the cache already holds below `start`.  Lanes
    /// with `len == 0` are untouched and get a zero logits row; a lane
    /// whose chunk reaches the end of its prompt reads its first-token
    /// logits from its row.
    ///
    /// Each lane's per-layer region of the `[nl, b, h, t, hd]` cache is
    /// itself a valid `b = 1` cache, so the lane runs through [`Fwd::block`]
    /// independently on a zero-copy slice.  Row `r` (absolute position
    /// `start + r`) is masked to attend `t <= start + r`: `block` scatters
    /// the whole chunk's K/V before attending, but the mask excludes the
    /// not-yet-visible later rows, so every row sees exactly the cache
    /// state a per-token decode would have — which is why a chunked
    /// prefill is bitwise identical to feeding the same tokens through
    /// single decode steps (and token-identical to one atomic prefill).
    #[allow(clippy::too_many_arguments)]
    fn chunk_prefill(
        &self,
        b: usize,
        ids: &[i32],
        tokens: &[i32],
        start: &[i32],
        len: &[i32],
        k_cache: &HostTensor,
        v_cache: &HostTensor,
    ) -> Result<Vec<HostTensor>> {
        let cfg = self.cfg;
        let t_max = cfg.max_seq;
        let mut kcs = k_cache.as_f32();
        let mut vcs = v_cache.as_f32();
        let lane_cache = cfg.n_heads * t_max * cfg.head_dim;
        let mut logits = vec![0f32; b * cfg.vocab];
        for lane in 0..b {
            let n = len[lane].max(0) as usize;
            if n == 0 {
                continue;
            }
            let s0 = (start[lane].max(0) as usize).min(t_max - 1);
            let n = n.min(t_max - s0);
            let slot = ids[lane].max(0) as usize;
            let slots = vec![slot; n];
            let rope_pos: Vec<usize> = (s0..s0 + n).collect();
            let write_pos = rope_pos.clone();
            let chunk: Vec<i32> = (0..n).map(|i| tokens[lane * t_max + s0 + i]).collect();
            let mut x = self.embed(&chunk)?;
            let visible = move |r: usize, t: usize| t <= s0 + r;
            for layer in 0..cfg.n_layers {
                let off = (layer * b + lane) * lane_cache;
                let (kc, vc) =
                    (&mut kcs[off..off + lane_cache], &mut vcs[off..off + lane_cache]);
                self.block(layer, &mut x, 1, n, &slots, &rope_pos, kc, vc, &write_pos, &visible)?;
            }
            let row = self.head_row(&x, n - 1)?;
            logits[lane * cfg.vocab..(lane + 1) * cfg.vocab].copy_from_slice(&row);
        }
        Ok(vec![
            HostTensor::f32(vec![b, cfg.vocab], logits),
            HostTensor::f32(self.cache_shape(b), kcs),
            HostTensor::f32(self.cache_shape(b), vcs),
        ])
    }

    /// model.py `decode`: one step for `b` slots at per-slot positions.
    fn decode(
        &self,
        b: usize,
        ids: &[i32],
        token: &[i32],
        pos: &[i32],
        k_cache: &HostTensor,
        v_cache: &HostTensor,
    ) -> Result<Vec<HostTensor>> {
        let cfg = self.cfg;
        let slots: Vec<usize> = ids.iter().map(|&s| s.max(0) as usize).collect();
        let posu: Vec<usize> =
            pos.iter().map(|&p| (p.max(0) as usize).min(cfg.max_seq - 1)).collect();
        let mut x = self.embed(token)?;
        let mut kcs = k_cache.as_f32();
        let mut vcs = v_cache.as_f32();

        let lane_cache = b * cfg.n_heads * cfg.max_seq * cfg.head_dim;
        let posv = posu.clone();
        let visible = move |r: usize, t: usize| t <= posv[r];
        for layer in 0..cfg.n_layers {
            let (kc, vc) = (
                &mut kcs[layer * lane_cache..(layer + 1) * lane_cache],
                &mut vcs[layer * lane_cache..(layer + 1) * lane_cache],
            );
            self.block(layer, &mut x, b, 1, &slots, &posu, kc, vc, &posu, &visible)?;
        }
        let mut logits = vec![0f32; b * cfg.vocab];
        for lane in 0..b {
            let row = self.head_row(&x, lane)?;
            logits[lane * cfg.vocab..(lane + 1) * cfg.vocab].copy_from_slice(&row);
        }
        Ok(vec![
            HostTensor::f32(vec![b, cfg.vocab], logits),
            HostTensor::f32(self.cache_shape(b), kcs),
            HostTensor::f32(self.cache_shape(b), vcs),
        ])
    }
}

// ---------------------------------------------------------------------------
// Paged-KV block copies (the cache-layout contract, owned here)
// ---------------------------------------------------------------------------

/// Validate a serving cache tensor shape `[n_layers, b, n_heads, max_seq,
/// head_dim]` and return its dimensions.
fn cache_dims(cache: &HostTensor) -> Result<[usize; 5]> {
    match cache.shape.as_slice() {
        &[nl, b, h, t, hd] => Ok([nl, b, h, t, hd]),
        s => bail!(
            "cache tensor has shape {s:?}, expected [n_layers, b, n_heads, max_seq, head_dim]"
        ),
    }
}

/// Copy cache positions `[start, start + n_tokens)` of one lane out of a
/// `[n_layers, b, n_heads, max_seq, head_dim]` cache tensor into a flat
/// `[n_layers, n_heads, n_tokens, head_dim]` block buffer.
///
/// This is the read half of the paged-KV block protocol
/// (docs/DESIGN.md §Paged KV): a published shared-prefix block is exactly
/// the bytes this gather produces, and [`scatter_cache_block`] writes
/// them back bit-identically, which is why shared-prefix admission and a
/// cold prefill are token-identical on this backend.
pub fn gather_cache_block(
    cache: &HostTensor,
    lane: usize,
    start: usize,
    n_tokens: usize,
) -> Result<Vec<f32>> {
    let [nl, b, h, t_max, hd] = cache_dims(cache)?;
    if lane >= b || start + n_tokens > t_max {
        bail!("block gather out of range: lane {lane}/{b}, tokens {start}+{n_tokens}/{t_max}");
    }
    let mut out = Vec::with_capacity(nl * h * n_tokens * hd);
    for l in 0..nl {
        for hh in 0..h {
            let off = (((l * b + lane) * h + hh) * t_max + start) * hd;
            out.extend_from_slice(&cache.read_f32_range(off, n_tokens * hd));
        }
    }
    Ok(out)
}

/// Write half of the paged-KV block protocol: copy a flat
/// `[n_layers, n_heads, n_tokens, head_dim]` block buffer (from
/// [`gather_cache_block`]) into one lane of a cache tensor at positions
/// `[start, start + n_tokens)`.
pub fn scatter_cache_block(
    cache: &mut HostTensor,
    lane: usize,
    start: usize,
    n_tokens: usize,
    block: &[f32],
) -> Result<()> {
    let [nl, b, h, t_max, hd] = cache_dims(cache)?;
    if lane >= b || start + n_tokens > t_max {
        bail!("block scatter out of range: lane {lane}/{b}, tokens {start}+{n_tokens}/{t_max}");
    }
    let row = n_tokens * hd;
    if block.len() != nl * h * row {
        bail!("block buffer has {} elems, expected {}", block.len(), nl * h * row);
    }
    let mut i = 0;
    for l in 0..nl {
        for hh in 0..h {
            let off = (((l * b + lane) * h + hh) * t_max + start) * hd;
            cache.write_f32_range(off, &block[i..i + row]);
            i += row;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfigInfo {
        synthetic_configs()["tiny"].clone()
    }

    /// Build the full positional input list for an entry: synthetic
    /// params, an identity adapter bank, and the given data tensors.
    fn entry_inputs(info: &EntryInfo, data: BTreeMap<&str, HostTensor>) -> Vec<HostTensor> {
        let cfg = synthetic_configs()[&info.config].clone();
        let params: BTreeMap<String, HostTensor> =
            synthetic_params(&cfg, &param_spec_list(&cfg)).into_iter().collect();
        info.inputs
            .iter()
            .map(|s| match s.group.as_str() {
                "params" => params[&s.name].clone(),
                "adapters" => identity_bank_tensor(s),
                _ => data[s.name.as_str()].clone(),
            })
            .collect()
    }

    #[test]
    fn synthetic_manifest_has_serving_entries_for_every_config() {
        let m = synthetic_manifest();
        assert!(m.synthetic);
        for c in ["tiny", "serve", "train", "train2"] {
            assert!(m.configs.contains_key(c));
            for mode in MODES {
                assert!(m.entries.contains_key(&format!("decode_{mode}_{c}_b2")));
                assert!(m.entries.contains_key(&format!("prefill_{mode}_{c}_b2_l16")));
            }
        }
        // Signatures match the aot.py positional convention.
        let e = &m.entries["decode_road_tiny_b2"];
        assert_eq!(e.inputs.last().unwrap().name, "v_cache");
        assert_eq!(e.outputs[0].shape, vec![2, 256]);
        let (start, end) = e.group_range("params");
        assert!(end > start, "params group present");
        // The advertised bucket metadata never contradicts the entry set.
        for &b in &m.serve_decode_batches {
            assert!(m.entries.contains_key(&format!("decode_road_serve_b{b}")));
        }
        for &(b, l) in &m.serve_prefill_buckets {
            assert!(
                m.entries.contains_key(&format!("prefill_road_serve_b{b}_l{l}")),
                "advertised bucket ({b}, {l}) has no entry"
            );
        }
    }

    #[test]
    fn synthetic_params_are_deterministic_and_structured() {
        let cfg = tiny();
        let specs = param_spec_list(&cfg);
        let a = synthetic_params(&cfg, &specs);
        let b = synthetic_params(&cfg, &specs);
        for ((n1, t1), (_, t2)) in a.iter().zip(&b) {
            assert_eq!(t1.bytes(), t2.bytes(), "nondeterministic param {n1}");
        }
        let by_name: BTreeMap<&str, &HostTensor> =
            a.iter().map(|(n, t)| (n.as_str(), t)).collect();
        assert_eq!(by_name["final_norm"].as_f32(), vec![1.0; cfg.d_model]);
        assert_eq!(
            by_name["blocks.0.wq.bias"].as_f32(),
            vec![0.0; cfg.d_model],
            "biases start at zero"
        );
        assert!(by_name["tok_emb"].as_f32().iter().any(|&v| v != 0.0));
    }

    /// Prefill of (prompt ++ next) must equal prefill(prompt) followed by
    /// one decode of `next` — the KV-cache semantics the engine's
    /// continuous batching depends on.
    #[test]
    fn decode_continues_prefill_exactly() {
        let m = synthetic_manifest();
        let cfg = tiny();
        let pre_info = &m.entries["prefill_road_tiny_b1_l16"];
        let dec_info = &m.entries["decode_road_tiny_b1"];
        let pre = RefEntry::from_info(pre_info, &cfg).unwrap();
        let dec = RefEntry::from_info(dec_info, &cfg).unwrap();

        let prompt = [17i32, 4, 99, 250];
        let next = 33i32;
        let mut padded = vec![0i32; 16];
        padded[..4].copy_from_slice(&prompt);
        let mut extended = padded.clone();
        extended[4] = next;

        let run_prefill = |tokens: Vec<i32>, len: i32| {
            let data = BTreeMap::from([
                ("ids", HostTensor::i32(vec![1], vec![0])),
                ("tokens", HostTensor::i32(vec![1, 16], tokens)),
                ("lengths", HostTensor::i32(vec![1], vec![len])),
            ]);
            pre.execute(&entry_inputs(pre_info, data)).unwrap()
        };
        let long = run_prefill(extended, 5);
        let short = run_prefill(padded, 4);

        let data = BTreeMap::from([
            ("ids", HostTensor::i32(vec![1], vec![0])),
            ("token", HostTensor::i32(vec![1], vec![next])),
            ("pos", HostTensor::i32(vec![1], vec![4])),
            ("k_cache", short[1].clone()),
            ("v_cache", short[2].clone()),
        ]);
        let stepped = dec.execute(&entry_inputs(dec_info, data)).unwrap();

        let (a, b) = (long[0].as_f32(), stepped[0].as_f32());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!((x - y).abs() < 1e-4, "logit {i}: prefill {x} vs decode {y}");
        }
    }

    /// Reference runs are bitwise deterministic, and each lane is
    /// independent of its batch neighbours (the batch-invariance behind
    /// the hetero-batching claim).
    #[test]
    fn lanes_are_batch_invariant() {
        let m = synthetic_manifest();
        let cfg = tiny();
        let d2 = &m.entries["decode_road_tiny_b2"];
        let d1 = &m.entries["decode_road_tiny_b1"];
        let dec2 = RefEntry::from_info(d2, &cfg).unwrap();
        let dec1 = RefEntry::from_info(d1, &cfg).unwrap();
        let n: usize =
            cfg.n_layers * cfg.n_heads * cfg.max_seq * cfg.head_dim;
        let mut rng = Rng::seed_from(5);
        let kc1: Vec<f32> = rng.normal_vec(n, 0.02);
        let vc1: Vec<f32> = rng.normal_vec(n, 0.02);
        let kc2: Vec<f32> = rng.normal_vec(n, 0.02);
        let vc2: Vec<f32> = rng.normal_vec(n, 0.02);
        let shape1 = vec![cfg.n_layers, 1, cfg.n_heads, cfg.max_seq, cfg.head_dim];
        let shape2 = vec![cfg.n_layers, 2, cfg.n_heads, cfg.max_seq, cfg.head_dim];
        // Interleave the two lanes' caches into the b=2 layout.
        let lane = cfg.n_heads * cfg.max_seq * cfg.head_dim;
        let mut kcb = vec![0f32; 2 * n];
        let mut vcb = vec![0f32; 2 * n];
        for layer in 0..cfg.n_layers {
            let (s, d) = (layer * lane, layer * 2 * lane);
            kcb[d..d + lane].copy_from_slice(&kc1[s..s + lane]);
            kcb[d + lane..d + 2 * lane].copy_from_slice(&kc2[s..s + lane]);
            vcb[d..d + lane].copy_from_slice(&vc1[s..s + lane]);
            vcb[d + lane..d + 2 * lane].copy_from_slice(&vc2[s..s + lane]);
        }
        let batch_data = BTreeMap::from([
            ("ids", HostTensor::i32(vec![2], vec![1, 2])),
            ("token", HostTensor::i32(vec![2], vec![7, 201])),
            ("pos", HostTensor::i32(vec![2], vec![3, 9])),
            ("k_cache", HostTensor::f32(shape2.clone(), kcb)),
            ("v_cache", HostTensor::f32(shape2, vcb)),
        ]);
        let batched = dec2.execute(&entry_inputs(d2, batch_data.clone())).unwrap();
        let again = dec2.execute(&entry_inputs(d2, batch_data)).unwrap();
        assert_eq!(batched[0].bytes(), again[0].bytes(), "bitwise deterministic");

        let solo_data = BTreeMap::from([
            ("ids", HostTensor::i32(vec![1], vec![1])),
            ("token", HostTensor::i32(vec![1], vec![7])),
            ("pos", HostTensor::i32(vec![1], vec![3])),
            ("k_cache", HostTensor::f32(shape1.clone(), kc1)),
            ("v_cache", HostTensor::f32(shape1, vc1)),
        ]);
        let solo = dec1.execute(&entry_inputs(d1, solo_data)).unwrap();
        let (sb, bb) = (solo[0].as_f32(), batched[0].as_f32());
        assert_eq!(
            &bb[..cfg.vocab],
            &sb[..],
            "lane 0 logits must be bitwise identical solo vs batched"
        );
    }

    /// Gather → scatter round-trips exactly: a block moved between lanes
    /// (and cache tensors) is a bit-identical copy, and positions outside
    /// the block are untouched.
    #[test]
    fn cache_block_gather_scatter_roundtrip_is_exact() {
        let cfg = tiny();
        let shape = vec![cfg.n_layers, 2, cfg.n_heads, cfg.max_seq, cfg.head_dim];
        let n: usize = shape.iter().product();
        let mut rng = Rng::seed_from(41);
        let src = HostTensor::f32(shape.clone(), rng.normal_vec(n, 0.1));
        let blk = gather_cache_block(&src, 1, 8, 4).unwrap();
        assert_eq!(blk.len(), cfg.n_layers * cfg.n_heads * 4 * cfg.head_dim);

        let mut dst = HostTensor::zeros(shape, DType::F32);
        scatter_cache_block(&mut dst, 0, 8, 4, &blk).unwrap();
        let back = gather_cache_block(&dst, 0, 8, 4).unwrap();
        assert_eq!(blk, back, "round-trip must be bit-identical");
        // Positions before/after the block stay untouched.
        let before = gather_cache_block(&dst, 0, 0, 8).unwrap();
        assert!(before.iter().all(|&v| v == 0.0));
        let after = gather_cache_block(&dst, 0, 12, 4).unwrap();
        assert!(after.iter().all(|&v| v == 0.0));
        // Out-of-range and wrong-size calls are typed errors.
        assert!(gather_cache_block(&src, 2, 0, 4).is_err());
        assert!(gather_cache_block(&src, 0, cfg.max_seq - 1, 2).is_err());
        assert!(scatter_cache_block(&mut dst, 0, 0, 4, &blk[1..]).is_err());
    }

    /// The paged-KV hit path at the reference level: prefill only a
    /// shared prefix, gather its blocks, scatter them into a fresh cache,
    /// then feed the rest of the prompt through decode steps.  The final
    /// logits must match a cold full-prompt prefill — the token-identity
    /// property the engine's shared-prefix admission rests on.
    #[test]
    fn decode_over_scattered_prefix_blocks_matches_cold_prefill() {
        let m = synthetic_manifest();
        let cfg = tiny();
        let pre_info = &m.entries["prefill_road_tiny_b1_l16"];
        let dec_info = &m.entries["decode_road_tiny_b1"];
        let pre = RefEntry::from_info(pre_info, &cfg).unwrap();
        let dec = RefEntry::from_info(dec_info, &cfg).unwrap();

        let prompt = [17i32, 4, 99, 250, 33, 8, 120, 7];
        let block = 4usize; // kv_block_size: positions [0,4) are the shared prefix
        let run_prefill = |len: usize| {
            let mut padded = vec![0i32; 16];
            padded[..len].copy_from_slice(&prompt[..len]);
            let data = BTreeMap::from([
                ("ids", HostTensor::i32(vec![1], vec![0])),
                ("tokens", HostTensor::i32(vec![1, 16], padded)),
                ("lengths", HostTensor::i32(vec![1], vec![len as i32])),
            ]);
            pre.execute(&entry_inputs(pre_info, data)).unwrap()
        };
        let cold = run_prefill(prompt.len());
        let prefix = run_prefill(block);

        // "Publish" the prefix block, then "adopt" it into a fresh lane.
        let kb = gather_cache_block(&prefix[1], 0, 0, block).unwrap();
        let vb = gather_cache_block(&prefix[2], 0, 0, block).unwrap();
        let shape = vec![cfg.n_layers, 1, cfg.n_heads, cfg.max_seq, cfg.head_dim];
        let mut kc = HostTensor::zeros(shape.clone(), DType::F32);
        let mut vc = HostTensor::zeros(shape, DType::F32);
        scatter_cache_block(&mut kc, 0, 0, block, &kb).unwrap();
        scatter_cache_block(&mut vc, 0, 0, block, &vb).unwrap();

        // Feed the remaining prompt tokens one decode step at a time.
        let mut outs = None;
        for p in block..prompt.len() {
            let data = BTreeMap::from([
                ("ids", HostTensor::i32(vec![1], vec![0])),
                ("token", HostTensor::i32(vec![1], vec![prompt[p]])),
                ("pos", HostTensor::i32(vec![1], vec![p as i32])),
                ("k_cache", kc.clone()),
                ("v_cache", vc.clone()),
            ]);
            let step = dec.execute(&entry_inputs(dec_info, data)).unwrap();
            kc = step[1].clone();
            vc = step[2].clone();
            outs = Some(step);
        }
        let warm = outs.unwrap();
        let (a, b) = (cold[0].as_f32(), warm[0].as_f32());
        let argmax = |v: &[f32]| {
            v.iter().enumerate().fold((0usize, f32::NEG_INFINITY), |acc, (i, &x)| {
                if x > acc.1 { (i, x) } else { acc }
            })
        };
        assert_eq!(argmax(&a).0, argmax(&b).0, "greedy token diverged");
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!((x - y).abs() < 1e-4, "logit {i}: cold {x} vs paged {y}");
        }
    }

    /// The tentpole identity: prefilling a prompt in chunks (continuing
    /// the lane's cache across calls) must be *bitwise* identical to
    /// feeding the same tokens through single decode steps, and
    /// token-identical to one atomic bucketed prefill — the property the
    /// engine's `--prefill-chunk` mixed steps rest on.
    #[test]
    fn chunked_prefill_matches_decode_steps_and_cold_prefill() {
        let m = synthetic_manifest();
        let cfg = tiny();
        let pre_info = &m.entries["prefill_road_tiny_b1_l16"];
        let dec_info = &m.entries["decode_road_tiny_b1"];
        let chk_info = &m.entries["chunk_prefill_road_tiny_b1"];
        let pre = RefEntry::from_info(pre_info, &cfg).unwrap();
        let dec = RefEntry::from_info(dec_info, &cfg).unwrap();
        let chk = RefEntry::from_info(chk_info, &cfg).unwrap();

        let prompt = [17i32, 4, 99, 250, 33, 8, 120, 7];
        let shape = vec![cfg.n_layers, 1, cfg.n_heads, cfg.max_seq, cfg.head_dim];

        // Chunked: 3 tokens, then the remaining 5, carrying the cache.
        let mut full = vec![0i32; cfg.max_seq];
        full[..prompt.len()].copy_from_slice(&prompt);
        let run_chunk = |s0: usize, n: usize, kc: HostTensor, vc: HostTensor| {
            let data = BTreeMap::from([
                ("ids", HostTensor::i32(vec![1], vec![0])),
                ("tokens", HostTensor::i32(vec![1, cfg.max_seq], full.clone())),
                ("start", HostTensor::i32(vec![1], vec![s0 as i32])),
                ("len", HostTensor::i32(vec![1], vec![n as i32])),
                ("k_cache", kc),
                ("v_cache", vc),
            ]);
            chk.execute(&entry_inputs(chk_info, data)).unwrap()
        };
        let first = run_chunk(
            0,
            3,
            HostTensor::zeros(shape.clone(), DType::F32),
            HostTensor::zeros(shape.clone(), DType::F32),
        );
        let chunked = run_chunk(3, 5, first[1].clone(), first[2].clone());

        // Decode-fed: the same prompt one token per step.
        let mut kc = HostTensor::zeros(shape.clone(), DType::F32);
        let mut vc = HostTensor::zeros(shape, DType::F32);
        let mut stepped = None;
        for (p, &tok) in prompt.iter().enumerate() {
            let data = BTreeMap::from([
                ("ids", HostTensor::i32(vec![1], vec![0])),
                ("token", HostTensor::i32(vec![1], vec![tok])),
                ("pos", HostTensor::i32(vec![1], vec![p as i32])),
                ("k_cache", kc.clone()),
                ("v_cache", vc.clone()),
            ]);
            let step = dec.execute(&entry_inputs(dec_info, data)).unwrap();
            kc = step[1].clone();
            vc = step[2].clone();
            stepped = Some(step);
        }
        let stepped = stepped.unwrap();
        assert_eq!(chunked[0].bytes(), stepped[0].bytes(), "logits: chunked vs decode-fed");
        assert_eq!(chunked[1].bytes(), stepped[1].bytes(), "k cache: chunked vs decode-fed");
        assert_eq!(chunked[2].bytes(), stepped[2].bytes(), "v cache: chunked vs decode-fed");

        // Atomic bucketed prefill of the whole prompt agrees on tokens.
        let mut padded = vec![0i32; 16];
        padded[..prompt.len()].copy_from_slice(&prompt);
        let data = BTreeMap::from([
            ("ids", HostTensor::i32(vec![1], vec![0])),
            ("tokens", HostTensor::i32(vec![1, 16], padded)),
            ("lengths", HostTensor::i32(vec![1], vec![prompt.len() as i32])),
        ]);
        let cold = pre.execute(&entry_inputs(pre_info, data)).unwrap();
        let (a, b) = (cold[0].as_f32(), chunked[0].as_f32());
        let argmax = |v: &[f32]| {
            v.iter()
                .enumerate()
                .fold(
                    (0usize, f32::NEG_INFINITY),
                    |acc, (i, &x)| if x > acc.1 { (i, x) } else { acc },
                )
                .0
        };
        assert_eq!(argmax(&a), argmax(&b), "greedy token diverged");
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!((x - y).abs() < 1e-4, "logit {i}: cold {x} vs chunked {y}");
        }
    }

    #[test]
    fn non_serving_entries_are_rejected() {
        let cfg = tiny();
        let mut info = synthetic_manifest().entries["decode_road_tiny_b2"].clone();
        info.kind = "train_step".into();
        let err = RefEntry::from_info(&info, &cfg).unwrap_err();
        assert!(err.to_string().contains("serving entries only"), "{err}");
        let mut info2 = synthetic_manifest().entries["decode_road_tiny_b2"].clone();
        info2.mode = Some("oft".into());
        assert!(RefEntry::from_info(&info2, &cfg).is_err());
    }

    #[test]
    fn road_entries_reject_odd_projection_widths_at_construction() {
        // RoAd pairs adjacent output elements; a config with an odd d_ff
        // would silently leave the last w1/w3 column unrotated.  The entry
        // constructor refuses it up front, before any decode step runs.
        let mut odd = tiny();
        odd.d_ff = 13;
        let info = synthetic_manifest().entries["decode_road_tiny_b2"].clone();
        let err = RefEntry::from_info(&info, &odd).unwrap_err().to_string();
        assert!(err.contains("even projection widths"), "{err}");
        assert!(err.contains("d_out 13"), "error names the odd width: {err}");
        // The same config is fine for the non-rotating modes.
        for mode in ["base", "lora", "ia3"] {
            let i = synthetic_manifest().entries[&format!("decode_{mode}_tiny_b2")].clone();
            assert!(RefEntry::from_info(&i, &odd).is_ok(), "mode {mode}");
        }
    }

    /// Tiny hand-built [`Fwd`] over one 1x2 linear layer, for kernel-level
    /// assertions that need full control of weights and banks.
    fn micro_fwd<'a>(
        mode: &'a str,
        params: &'a BTreeMap<&'a str, &'a HostTensor>,
        adapters: &'a BTreeMap<&'a str, &'a HostTensor>,
        cfg: &'a ModelConfigInfo,
    ) -> Fwd<'a> {
        Fwd { cfg, mode, params, adapters, fused: true }
    }

    #[test]
    fn zero_activation_times_nan_weight_propagates_through_linear() {
        // The old `if xv == 0.0 { continue; }` sparsity skip made
        // 0 · NaN = 0 — diverging from IEEE and from PJRT, and masking
        // poisoned weights exactly when an activation happened to be zero.
        let cfg = tiny();
        let w = HostTensor::f32(vec![1, 2], vec![f32::NAN, 3.0]);
        let b = HostTensor::f32(vec![2], vec![1.0, 1.0]);
        let params = BTreeMap::from([("wq", &w), ("wq.bias", &b)]);
        let adapters = BTreeMap::new();
        let fwd = micro_fwd("base", &params, &adapters, &cfg);
        let z = fwd.linear("wq", &[0.0], 1, &[0], 1, 2).unwrap();
        assert!(z[0].is_nan(), "0 * NaN must stay NaN, got {}", z[0]);
        assert_eq!(z[1], 1.0, "bias + 0*3.0");
    }

    #[test]
    fn out_of_range_bank_slot_is_a_typed_error_not_a_panic() {
        // One-slot identity bank, row asks for slot 7: the epilogue's
        // bounds-checked BankView turns that into an error naming the
        // bank key instead of a slice panic mid-decode.
        let cfg = tiny();
        let w = HostTensor::f32(vec![1, 2], vec![1.0, 1.0]);
        let b = HostTensor::f32(vec![2], vec![0.0, 0.0]);
        let r1 = HostTensor::f32(vec![1, 2], vec![1.0, 1.0]);
        let r2 = HostTensor::f32(vec![1, 2], vec![0.0, 0.0]);
        let params = BTreeMap::from([("wq", &w), ("wq.bias", &b)]);
        let adapters = BTreeMap::from([("wq.r1", &r1), ("wq.r2", &r2)]);
        let fwd = micro_fwd("road", &params, &adapters, &cfg);
        let err = fwd.linear("wq", &[1.0], 1, &[7], 1, 2).unwrap_err().to_string();
        assert!(err.contains("slot 7 out of range"), "{err}");
        assert!(err.contains("wq.r1"), "error names the bank key: {err}");
    }
}
