// 18 call sites: one over the budget of 17.
fn gated_01() {
    require_artifacts!();
}

fn gated_02() {
    require_artifacts!();
}

fn gated_03() {
    require_artifacts!();
}

fn gated_04() {
    require_artifacts!();
}

fn gated_05() {
    require_artifacts!();
}

fn gated_06() {
    require_artifacts!();
}

fn gated_07() {
    require_artifacts!();
}

fn gated_08() {
    require_artifacts!();
}

fn gated_09() {
    require_artifacts!();
}

fn gated_10() {
    require_artifacts!();
}

fn gated_11() {
    require_artifacts!();
}

fn gated_12() {
    require_artifacts!();
}

fn gated_13() {
    require_artifacts!();
}

fn gated_14() {
    require_artifacts!();
}

fn gated_15() {
    require_artifacts!();
}

fn gated_16() {
    require_artifacts!();
}

fn gated_17() {
    require_artifacts!();
}

fn gated_18() {
    require_artifacts!();
}

