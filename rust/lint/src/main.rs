//! `roadlint` CLI.
//!
//! ```text
//! cargo run -p roadlint -- check [--json] [--root DIR]
//! cargo run -p roadlint -- rules
//! ```
//!
//! `check` exits 0 when the repo is clean, 1 on any unallowed finding,
//! 2 on usage/IO errors.  `--json` emits the findings as a JSON array
//! (stable field order) for CI and tooling; the default is
//! `path:line: [rule] message`, one finding per line.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd = None;
    let mut json = false;
    let mut root = PathBuf::from(".");
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "check" | "rules" if cmd.is_none() => cmd = Some(a.clone()),
            "--json" => json = true,
            "--root" => match it.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => return usage("--root needs a directory"),
            },
            other => return usage(&format!("unknown argument {other:?}")),
        }
    }
    match cmd.as_deref() {
        Some("rules") => {
            for rule in roadlint::rules::registry() {
                println!("{:24} {}", rule.name, rule.description);
            }
            ExitCode::SUCCESS
        }
        Some("check") => check(&root, json),
        _ => usage("expected a command: check | rules"),
    }
}

fn check(root: &std::path::Path, json: bool) -> ExitCode {
    let findings = match roadlint::check(root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("roadlint: {e}");
            return ExitCode::from(2);
        }
    };
    if json {
        println!("{}", roadlint::findings_to_json(&findings));
    } else {
        for f in &findings {
            println!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.message);
        }
        if findings.is_empty() {
            println!("roadlint: clean ({} rules)", roadlint::rules::registry().len());
        } else {
            println!("roadlint: {} finding(s)", findings.len());
        }
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn usage(err: &str) -> ExitCode {
    eprintln!("roadlint: {err}\nusage: roadlint check [--json] [--root DIR] | roadlint rules");
    ExitCode::from(2)
}
