//! Statistics helpers: summary stats, percentiles, latency histograms,
//! correlation metrics used by the evaluation suites (Pearson for the
//! STS-B analogue, Matthews correlation for the CoLA analogue).

#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 0.50),
        p90: percentile_sorted(&sorted, 0.90),
        p99: percentile_sorted(&sorted, 0.99),
    }
}

/// Merge per-replica [`Summary`]s into one fleet-level summary without the
/// raw samples (they never cross the engine-thread channel).  Counts sum,
/// the mean is the sample-weighted mean, min/max are exact, and std is the
/// pooled standard deviation.  Percentiles are the sample-weighted average
/// of the parts' percentiles — an approximation (exact when the parts are
/// identically distributed) that is fine for the fleet dashboard; any
/// byte-accounted study computes its percentiles from raw records instead.
pub fn merge_summaries<'a>(parts: impl IntoIterator<Item = &'a Summary>) -> Summary {
    let mut out = Summary::default();
    let mut m2 = 0.0; // sum of n_i * (std_i^2 + mean_i^2)
    let mut first = true;
    for s in parts {
        if s.n == 0 {
            continue;
        }
        let w = s.n as f64;
        out.mean += w * s.mean;
        m2 += w * (s.std * s.std + s.mean * s.mean);
        out.p50 += w * s.p50;
        out.p90 += w * s.p90;
        out.p99 += w * s.p99;
        out.min = if first { s.min } else { out.min.min(s.min) };
        out.max = if first { s.max } else { out.max.max(s.max) };
        out.n += s.n;
        first = false;
    }
    if out.n == 0 {
        return Summary::default();
    }
    let n = out.n as f64;
    out.mean /= n;
    out.p50 /= n;
    out.p90 /= n;
    out.p99 /= n;
    out.std = (m2 / n - out.mean * out.mean).max(0.0).sqrt();
    out
}

pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn std(xs: &[f64]) -> f64 {
    summarize(xs).std
}

/// Pearson correlation (the paper's STS-B metric).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    if x.len() < 2 {
        return 0.0;
    }
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..x.len() {
        let a = x[i] - mx;
        let b = y[i] - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx.sqrt() * dy.sqrt())
    }
}

/// Matthews correlation coefficient for binary labels (the CoLA metric).
pub fn matthews(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    let (mut tp, mut tn, mut fp, mut fnn) = (0f64, 0f64, 0f64, 0f64);
    for i in 0..pred.len() {
        match (pred[i], gold[i]) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fnn += 1.0,
            _ => panic!("matthews expects binary labels"),
        }
    }
    let denom = ((tp + fp) * (tp + fnn) * (tn + fp) * (tn + fnn)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fnn) / denom
    }
}

pub fn accuracy(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(gold).filter(|(a, b)| a == b).count() as f64 / pred.len() as f64
}

/// Streaming latency recorder (microsecond samples).
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    samples_us: Vec<f64>,
}

impl LatencyRecorder {
    pub fn record(&mut self, dur: std::time::Duration) {
        self.samples_us.push(dur.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    /// Record a unitless sample (the recorder doubles as a plain value
    /// histogram, e.g. for queue depths).
    pub fn record_value(&mut self, v: f64) {
        self.samples_us.push(v);
    }

    pub fn summary(&self) -> Summary {
        summarize(&self.samples_us)
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn clear(&mut self) {
        self.samples_us.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn pearson_perfect() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-9);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn matthews_perfect_and_random() {
        assert!((matthews(&[1, 0, 1, 0], &[1, 0, 1, 0]) - 1.0).abs() < 1e-9);
        assert!((matthews(&[1, 1, 0, 0], &[1, 0, 1, 0])).abs() < 1e-9);
    }

    #[test]
    fn accuracy_counts() {
        assert!((accuracy(&[1, 2, 3], &[1, 2, 0]) - 2.0 / 3.0).abs() < 1e-9);
    }
}
