//! End-to-end driver: the full system on a real small workload, proving
//! all three layers compose (EXPERIMENTS.md §E2E records a run).
//!
//!   1. ensure a pretrained backbone exists (pretraining = full finetuning
//!      on the generic corpus, driven through the train-step HLO),
//!   2. finetune a RoAd₁ adapter on the arithmetic suite for a few hundred
//!      steps, logging the loss curve,
//!   3. evaluate generative exact-match through the serving engine,
//!   4. register the trained adapter alongside a second user's adapter and
//!      serve a heterogeneous batch, reporting latency/throughput.
//!
//! ```bash
//! cargo run --release --example e2e_train_serve
//! ```

use std::rc::Rc;

use anyhow::Result;

use road::adapters::{Adapter, RoadAdapter};
use road::coordinator::engine::{Engine, EngineConfig};
use road::coordinator::request::{Request, SamplingParams};
use road::runtime::Runtime;
use road::tasks::{self, SuiteSampler};
use road::trainer::{self, Recipe, Trainer};
use road::util::rng::Rng;

fn main() -> Result<()> {
    let rt = Rc::new(Runtime::from_default_artifacts()?);
    let config = "train";

    // --- 1. backbone -------------------------------------------------------
    let pretrained = rt.manifest.artifact_path(&format!("pretrained_{config}.bin"));
    if !pretrained.exists() {
        println!("[e2e] no pretrained backbone; running a short pretrain (600 steps)...");
        let mut tr = Trainer::new(rt.clone(), config, "full")?;
        let corpus = tasks::pretrain_corpus();
        let recipe = Recipe { lr: 1e-3, steps: 600, warmup_ratio: 0.1, seed: 0, eval_every: 0, log_every: 100 };
        let mut src = SuiteSampler::new(&corpus, tr.batch, tr.seq_len);
        let rep = trainer::train(&mut tr, &recipe, &mut src, None)?;
        println!("[e2e] pretrain: {}", rep.summary_line());
        tr.merged_params()?.save(&pretrained)?;
    } else {
        println!("[e2e] using existing pretrained backbone");
    }

    // --- 2. finetune RoAd1 on arithmetic ------------------------------------
    let mut tr = Trainer::new(rt.clone(), config, "road1")?;
    println!(
        "[e2e] finetuning road1: {} trainable params ({:.3}% of backbone)",
        tr.n_trainable,
        100.0 * tr.n_trainable as f64
            / road::model::ParamStore::load_pretrained(&rt.manifest, config)?.n_params() as f64
    );
    let suite = tasks::arithmetic_train_suite();
    let recipe = Recipe { lr: 3e-3, steps: 300, warmup_ratio: 0.1, seed: 0, eval_every: 0, log_every: 50 };
    let mut src = SuiteSampler::new(&suite, tr.batch, tr.seq_len);
    let report = trainer::train(&mut tr, &recipe, &mut src, None)?;
    println!("[e2e] finetune: {}", report.summary_line());
    println!(
        "[e2e] loss curve (every 30 steps): {:?}",
        report
            .losses
            .iter()
            .step_by(30)
            .map(|l| (l * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );

    // --- 3. generative eval through the engine ------------------------------
    let econf = EngineConfig {
        model: config.into(),
        mode: "road".into(),
        decode_slots: 8,
        queue_capacity: 1024,
        ..Default::default()
    };
    let mut engine = Engine::new(rt.clone(), econf)?;
    let adapter = tr.export_adapter()?;
    engine.register_adapter("math", &adapter)?;
    for task in tasks::arithmetic_eval_suite() {
        if task.metric() != tasks::Metric::ExactMatch {
            continue;
        }
        let ev = tasks::eval_exact_match(&mut engine, Some("math"), task.as_ref(), 32, 99)?;
        println!("[e2e] {:<10} exact match = {:.3}", ev.task, ev.score);
    }

    // --- 4. heterogeneous serving ------------------------------------------
    let mut rng = Rng::seed_from(5);
    engine.register_adapter("other-user", &Adapter::Road(RoadAdapter::random(&engine.cfg, &mut rng, 0.1)))?;
    let mut reqs = Vec::new();
    for i in 0..16u64 {
        let prompt = if i % 2 == 0 { "12+34=" } else { "7+8=" };
        let adapter = if i % 2 == 0 { "math" } else { "other-user" };
        reqs.push(
            Request::new(road::tokenizer::encode(prompt), 6)
                .with_adapter(adapter)
                .with_sampling(SamplingParams { temperature: 0.0, top_k: 0, seed: 0, stop_token: Some(b'.' as i32) }),
        );
    }
    let t0 = std::time::Instant::now();
    let outs = engine.run_all(reqs)?;
    let wall = t0.elapsed().as_secs_f64();
    for o in outs.iter().take(4) {
        println!(
            "[e2e] req {} ({:?}) -> {:?}",
            o.id,
            o.adapter,
            road::tokenizer::decode(&o.tokens)
        );
    }
    println!("[e2e] served {} heterogeneous requests in {wall:.2}s", outs.len());
    println!("[e2e] {}", engine.metrics.report());
    Ok(())
}
