pub fn route(ready: &[usize]) -> usize {
    *ready.first().unwrap()
}

pub fn home(placement: Option<usize>) -> usize {
    placement.expect("adapter registered")
}

pub fn guarded(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
