//! NDJSON-over-TCP front end: the engine's wire protocol (std::net +
//! threads; the image carries no tokio or HTTP stack — docs/DESIGN.md
//! §Substitutions).
//!
//! One JSON object per line in, one JSON event per line out
//! (docs/DESIGN.md §Streaming protocol for the full grammar):
//!
//! ```text
//! → {"op":"generate","text":"hello","max_new_tokens":8,"adapter":"a","tag":1}
//! ← {"event":"admitted","id":3,"tag":1}
//! ← {"event":"token","id":3,"token":104,"pos":0,"ttft_ms":2.1,"tag":1}
//! ← {"event":"finished","id":3,"finish":"max_tokens","tokens":[...],"text":"...","tag":1}
//! → {"op":"cancel","id":3}
//! → {"op":"stats"}
//! ← {"event":"stats","stats":{...}}
//! ```
//!
//! Requests on one connection run concurrently (each `generate` gets a
//! streaming thread; lines are interleaved per event, never split).  The
//! optional `tag` is echoed verbatim on every event of that request so
//! clients can correlate before they learn the engine-issued id.  A
//! dropped connection cancels its in-flight requests via the
//! [`Generation`] drop path — a hung-up client frees its decode slots.
//!
//! Peer input is treated as hostile: request lines are capped at
//! [`MAX_LINE_BYTES`] (overflow is discarded, not buffered) and the JSON
//! parser bounds its recursion depth, so no line a peer can send panics
//! or exhausts the connection thread — every malformed input comes back
//! as a typed `invalid` event on the same connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::util::json::{self, Json};

use super::queue::EngineError;
use super::request::{Request, RequestOutput, SamplingParams, StreamEvent};
use super::server::{EngineClient, Generation};

/// Accept loop: one handler thread per connection, forever.  Callers bind
/// the listener themselves (so `--listen 127.0.0.1:0` can report the
/// chosen port before entering the loop).
pub fn serve(listener: TcpListener, client: EngineClient) -> Result<()> {
    for conn in listener.incoming() {
        match conn {
            Ok(stream) => {
                let client = client.clone();
                let spawned =
                    std::thread::Builder::new().name("road-conn".into()).spawn(move || {
                        let peer = stream
                            .peer_addr()
                            .map(|a| a.to_string())
                            .unwrap_or_else(|_| "<unknown>".into());
                        if let Err(e) = handle_conn(stream, client) {
                            eprintln!("[serve] connection {peer}: {e:#}");
                        }
                    });
                // A transient spawn failure (fd/thread pressure) costs one
                // connection, not the whole front door — same policy as an
                // accept error below.
                if let Err(e) = spawned {
                    eprintln!("[serve] could not spawn connection thread: {e}");
                }
            }
            Err(e) => eprintln!("[serve] accept error: {e}"),
        }
    }
    Ok(())
}

/// One parsed request line.
enum WireCmd {
    Generate(Request, Option<Json>),
    Cancel(u64),
    Stats,
}

/// Upper bound on one request line.  `BufRead::lines` buffers however
/// many bytes the peer sends before the next `\n`, so an endless
/// newline-free stream would grow the connection thread's memory without
/// limit.  Past this cap the rest of the line is *discarded* (never
/// buffered), the peer gets a typed `invalid` event, and the connection
/// resyncs at the next newline.
const MAX_LINE_BYTES: usize = 1 << 20;

/// One bounded read from the wire (see [`MAX_LINE_BYTES`]).
enum LineRead {
    /// A complete line (without its `\n`), within the cap.
    Line(String),
    /// The line ran past the cap; payload is the total length seen.  The
    /// overflow was discarded chunk-by-chunk, and the reader is
    /// positioned just after the terminating newline (or at EOF).
    TooLong(usize),
    Eof,
}

/// Read up to the next `\n` without ever holding more than
/// [`MAX_LINE_BYTES`] + one `BufReader` chunk in memory.
fn read_line_bounded(r: &mut impl BufRead) -> std::io::Result<LineRead> {
    let mut line: Vec<u8> = Vec::new();
    let mut dropped = 0usize;
    loop {
        let (consumed, saw_newline) = {
            let chunk = r.fill_buf()?;
            if chunk.is_empty() {
                return Ok(if dropped > 0 {
                    LineRead::TooLong(line.len() + dropped)
                } else if line.is_empty() {
                    LineRead::Eof
                } else {
                    LineRead::Line(String::from_utf8_lossy(&line).into_owned())
                });
            }
            let upto = chunk.iter().position(|&b| b == b'\n');
            let n = upto.unwrap_or(chunk.len());
            if dropped == 0 && line.len() + n <= MAX_LINE_BYTES {
                line.extend_from_slice(&chunk[..n]);
            } else {
                dropped += n;
            }
            // +1 swallows the newline itself.
            (n + usize::from(upto.is_some()), upto.is_some())
        };
        r.consume(consumed);
        if saw_newline {
            return Ok(if dropped > 0 {
                LineRead::TooLong(line.len() + dropped)
            } else {
                LineRead::Line(String::from_utf8_lossy(&line).into_owned())
            });
        }
    }
}

fn handle_conn(stream: TcpStream, client: EngineClient) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let writer = Arc::new(Mutex::new(stream));
    loop {
        let line = match read_line_bounded(&mut reader)? {
            LineRead::Eof => return Ok(()),
            LineRead::TooLong(n) => {
                let err = EngineError::Invalid {
                    reason: format!(
                        "request line of {n} bytes exceeds the {MAX_LINE_BYTES}-byte limit"
                    ),
                };
                write_line(&writer, &error_event(None, None, &err))?;
                continue;
            }
            LineRead::Line(l) => l,
        };
        if line.trim().is_empty() {
            continue;
        }
        match parse_line(&line) {
            Ok(WireCmd::Generate(req, tag)) => {
                let client = client.clone();
                let writer = writer.clone();
                std::thread::Builder::new().name("road-stream".into()).spawn(move || {
                    stream_generation(&client, req, tag, &writer);
                })?;
            }
            Ok(WireCmd::Cancel(id)) => {
                // Best-effort; unknown/finished ids are no-ops by design.
                let _ = client.cancel(id);
            }
            Ok(WireCmd::Stats) => {
                let line = match client.stats() {
                    Ok(snap) => json::obj(vec![
                        ("event", json::s("stats")),
                        ("stats", snap.to_json()),
                    ]),
                    Err(e) => error_event(None, None, &e),
                };
                write_line(&writer, &line)?;
            }
            Err(e) => {
                let err = EngineError::Invalid { reason: format!("{e:#}") };
                write_line(&writer, &error_event(None, None, &err))?;
            }
        }
    }
}

/// Drive one generation, relaying every stream event as an NDJSON line.
/// A failed write means the client hung up: returning drops the
/// [`Generation`], which auto-cancels the request in the engine.
fn stream_generation(
    client: &EngineClient,
    req: Request,
    tag: Option<Json>,
    writer: &Arc<Mutex<TcpStream>>,
) {
    let mut generation: Generation = match client.submit(req) {
        Ok(g) => g,
        Err(e) => {
            let _ = write_line(writer, &error_event(None, tag.as_ref(), &e));
            return;
        }
    };
    while let Some(ev) = generation.recv() {
        if write_line(writer, &event_json(&ev, tag.as_ref())).is_err() {
            return;
        }
        if ev.is_terminal() {
            return;
        }
    }
}

fn write_line(writer: &Arc<Mutex<TcpStream>>, v: &Json) -> Result<()> {
    let mut line = v.to_string_compact();
    line.push('\n');
    let mut w = writer.lock().map_err(|_| anyhow!("writer poisoned"))?;
    w.write_all(line.as_bytes())?;
    w.flush()?;
    Ok(())
}

fn parse_line(line: &str) -> Result<WireCmd> {
    let v = Json::parse(line)?;
    let op = v.opt("op").map(|o| o.as_str()).transpose()?.unwrap_or("generate");
    match op {
        "generate" => {
            let req = parse_generate(&v)?;
            Ok(WireCmd::Generate(req, v.opt("tag").cloned()))
        }
        "cancel" => {
            let id = v.get("id")?.as_f64()? as u64;
            Ok(WireCmd::Cancel(id))
        }
        "stats" => Ok(WireCmd::Stats),
        other => bail!("unknown op {other:?} (generate|cancel|stats)"),
    }
}

fn parse_generate(v: &Json) -> Result<Request> {
    let prompt: Vec<i32> = match (v.opt("prompt"), v.opt("text")) {
        (Some(arr), _) => arr
            .as_arr()?
            .iter()
            .map(|t| t.as_f64().map(|f| f as i32))
            .collect::<Result<_>>()?,
        (None, Some(text)) => crate::tokenizer::encode(text.as_str()?),
        (None, None) => bail!("generate needs \"prompt\" (token array) or \"text\""),
    };
    let max_new = v.opt("max_new_tokens").map(|n| n.as_usize()).transpose()?.unwrap_or(16);
    let mut req = Request::new(prompt, max_new);
    if let Some(a) = v.opt("adapter") {
        req = req.with_adapter(a.as_str()?);
    }
    if let Some(p) = v.opt("priority") {
        let p = p.as_f64()?;
        // The priority policy's tiers are a u8; anything else is a typed
        // `invalid` error event, not a silent clamp.
        if !(0.0..=255.0).contains(&p) || p.fract() != 0.0 {
            bail!("priority must be an integer in [0, 255], got {p}");
        }
        req = req.with_priority(p as u8);
    }
    if let Some(ms) = v.opt("deadline_ms") {
        let ms = ms.as_f64()?;
        // Validate before Duration::from_secs_f64, which panics on
        // negative/NaN/overflowing input — a malformed field must produce
        // the typed `invalid` error event, not kill the connection thread.
        if !ms.is_finite() || !(0.0..=1e13).contains(&ms) {
            bail!("deadline_ms must be a finite number of milliseconds in [0, 1e13], got {ms}");
        }
        req = req.with_deadline(Duration::from_secs_f64(ms / 1e3));
    }
    let sampling = SamplingParams {
        temperature: v.opt("temperature").map(|t| t.as_f64()).transpose()?.unwrap_or(0.0) as f32,
        top_k: v.opt("top_k").map(|t| t.as_usize()).transpose()?.unwrap_or(0),
        seed: v.opt("seed").map(|t| t.as_f64()).transpose()?.unwrap_or(0.0) as u64,
        // `null` means "no stop token"; anything else must be a number —
        // swallowing a malformed value here would silently run the request
        // to max_new_tokens while every other field errors loudly.
        stop_token: v
            .opt("stop_token")
            .filter(|t| !matches!(t, Json::Null))
            .map(|t| t.as_f64().map(|f| f as i32))
            .transpose()?,
    };
    Ok(req.with_sampling(sampling))
}

fn with_tag(mut pairs: Vec<(&'static str, Json)>, tag: Option<&Json>) -> Json {
    if let Some(t) = tag {
        pairs.push(("tag", t.clone()));
    }
    json::obj(pairs)
}

fn event_json(ev: &StreamEvent, tag: Option<&Json>) -> Json {
    match ev {
        StreamEvent::Admitted { id } => with_tag(
            vec![("event", json::s("admitted")), ("id", json::num(*id as f64))],
            tag,
        ),
        StreamEvent::Token { id, token, pos, ttft_hint } => {
            let mut pairs = vec![
                ("event", json::s("token")),
                ("id", json::num(*id as f64)),
                ("token", json::num(*token as f64)),
                ("pos", json::num(*pos as f64)),
            ];
            if let Some(t) = ttft_hint {
                pairs.push(("ttft_ms", json::num(t * 1e3)));
            }
            with_tag(pairs, tag)
        }
        StreamEvent::Finished(out) => finished_event(out, tag),
        StreamEvent::Error { id, error } => with_tag(
            vec![
                ("event", json::s("error")),
                ("id", json::num(*id as f64)),
                ("error", json::s(error.kind())),
                ("message", json::s(&error.to_string())),
            ],
            tag,
        ),
    }
}

fn finished_event(out: &RequestOutput, tag: Option<&Json>) -> Json {
    let mut pairs = vec![
        ("event", json::s("finished")),
        ("id", json::num(out.id as f64)),
        ("finish", json::s(out.finish.as_str())),
        (
            "tokens",
            json::arr(out.tokens.iter().map(|&t| json::num(t as f64)).collect()),
        ),
        ("text", json::s(&crate::tokenizer::decode(&out.tokens))),
        ("ttft_ms", json::num(out.ttft * 1e3)),
        ("e2e_ms", json::num(out.e2e * 1e3)),
    ];
    if let Some(a) = &out.adapter {
        pairs.push(("adapter", json::s(a)));
    }
    with_tag(pairs, tag)
}

fn error_event(id: Option<u64>, tag: Option<&Json>, e: &EngineError) -> Json {
    with_tag(
        vec![
            ("event", json::s("error")),
            ("id", id.map(|i| json::num(i as f64)).unwrap_or(Json::Null)),
            ("error", json::s(e.kind())),
            ("message", json::s(&e.to_string())),
        ],
        tag,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    #[test]
    fn parses_generate_with_all_fields() {
        let line = r#"{"op":"generate","prompt":[1,2,3],"max_new_tokens":5,"adapter":"a",
                       "temperature":0.5,"top_k":4,"seed":9,"stop_token":46,
                       "deadline_ms":250,"priority":2,"tag":"x"}"#
            .replace('\n', " ");
        let WireCmd::Generate(req, tag) = parse_line(&line).unwrap() else {
            panic!("expected generate")
        };
        assert_eq!(req.prompt, vec![1, 2, 3]);
        assert_eq!(req.max_new_tokens, 5);
        assert_eq!(req.adapter.as_deref(), Some("a"));
        assert_eq!(req.sampling.top_k, 4);
        assert_eq!(req.sampling.seed, 9);
        assert_eq!(req.sampling.stop_token, Some(46));
        assert_eq!(req.deadline, Some(Duration::from_millis(250)));
        assert_eq!(req.priority, 2);
        assert_eq!(tag, Some(json::s("x")));
    }

    #[test]
    fn priority_is_validated_not_clamped() {
        let WireCmd::Generate(req, _) = parse_line(r#"{"text":"x"}"#).unwrap() else {
            panic!("expected generate")
        };
        assert_eq!(req.priority, 0, "default tier");
        assert!(parse_line(r#"{"text":"x","priority":999}"#).is_err());
        assert!(parse_line(r#"{"text":"x","priority":-1}"#).is_err());
        assert!(parse_line(r#"{"text":"x","priority":1.5}"#).is_err());
        let WireCmd::Generate(req, _) = parse_line(r#"{"text":"x","priority":255}"#).unwrap()
        else {
            panic!("expected generate")
        };
        assert_eq!(req.priority, 255);
    }

    #[test]
    fn generate_is_the_default_op_and_text_tokenizes() {
        let WireCmd::Generate(req, tag) = parse_line(r#"{"text":"hi"}"#).unwrap() else {
            panic!("expected generate")
        };
        assert_eq!(req.prompt, crate::tokenizer::encode("hi"));
        assert_eq!(req.max_new_tokens, 16, "default budget");
        assert!(tag.is_none());
    }

    #[test]
    fn rejects_missing_prompt_and_unknown_op() {
        assert!(parse_line(r#"{"op":"generate"}"#).is_err());
        assert!(parse_line(r#"{"op":"frobnicate"}"#).is_err());
        assert!(parse_line("not json").is_err());
    }

    #[test]
    fn rejects_unconvertible_deadlines_instead_of_panicking() {
        // Duration::from_secs_f64 panics on these; the parser must turn
        // them into typed errors before they reach it.
        assert!(parse_line(r#"{"text":"x","deadline_ms":-5}"#).is_err());
        assert!(parse_line(r#"{"text":"x","deadline_ms":1e300}"#).is_err());
        assert!(parse_line(r#"{"text":"x","deadline_ms":0}"#).is_ok(), "zero budget is valid");
    }

    #[test]
    fn stop_token_is_strict_but_nullable() {
        let WireCmd::Generate(req, _) =
            parse_line(r#"{"text":"x","stop_token":null}"#).unwrap()
        else {
            panic!("expected generate")
        };
        assert_eq!(req.sampling.stop_token, None, "null means no stop token");
        assert!(
            parse_line(r#"{"text":"x","stop_token":"."}"#).is_err(),
            "non-numeric stop_token must error loudly, not run to max_new_tokens"
        );
    }

    #[test]
    fn parses_cancel_and_stats() {
        assert!(matches!(parse_line(r#"{"op":"cancel","id":7}"#).unwrap(), WireCmd::Cancel(7)));
        assert!(matches!(parse_line(r#"{"op":"stats"}"#).unwrap(), WireCmd::Stats));
        assert!(parse_line(r#"{"op":"cancel"}"#).is_err(), "cancel needs an id");
    }

    /// Wire-level robustness over a real loopback connection (reference
    /// backend, no artifacts): malformed JSON, an unknown op, a missing
    /// prompt, an out-of-range priority, an oversized prompt, a
    /// stack-hostile deeply nested document, and a line past the
    /// [`MAX_LINE_BYTES`] wire cap each yield a typed `invalid` error
    /// event — no panic, no disconnect — and the same connection then
    /// serves a valid request to completion.
    #[test]
    fn bad_lines_yield_typed_invalid_and_connection_survives() {
        use crate::coordinator::engine::EngineConfig;
        use crate::coordinator::server::EngineServer;
        use std::net::TcpListener;

        let econf = EngineConfig {
            model: "tiny".into(),
            mode: "base".into(),
            decode_slots: 2,
            queue_capacity: 16,
            backend: crate::runtime::BackendKind::Reference,
            ..Default::default()
        };
        let (server, client) =
            EngineServer::start(econf, crate::manifest::Manifest::default_dir(), |_| Ok(()))
                .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = serve(listener, client);
        });

        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut round_trip = |line: &str| -> Json {
            conn.write_all(line.as_bytes()).unwrap();
            conn.write_all(b"\n").unwrap();
            let mut out = String::new();
            assert!(reader.read_line(&mut out).unwrap() > 0, "connection closed after {line:?}");
            Json::parse(out.trim()).unwrap()
        };

        // The tiny model's largest prefill bucket is 16 tokens; 99 zeros
        // overflow it — rejected by the engine, not the parser.
        let oversized = format!(
            "{{\"op\":\"generate\",\"prompt\":[{}]}}",
            vec!["1"; 99].join(",")
        );
        // Deep enough to overflow the connection thread's stack if the
        // JSON parser recursed without a depth cap.
        let deep = "[".repeat(100_000) + &"]".repeat(100_000);
        let bad_lines = [
            "this is not json",
            r#"{"op":"frobnicate"}"#,
            r#"{"op":"generate"}"#,
            r#"{"op":"generate","text":"x","priority":999}"#,
            oversized.as_str(),
            deep.as_str(),
        ];
        for line in bad_lines {
            let ev = round_trip(line);
            assert_eq!(
                ev.get("event").unwrap().as_str().unwrap(),
                "error",
                "expected error event for {line:?}"
            );
            assert_eq!(
                ev.get("error").unwrap().as_str().unwrap(),
                EngineError::Invalid { reason: String::new() }.kind(),
                "stable `invalid` kind for {line:?}"
            );
        }

        // A line past the wire cap is discarded without being buffered
        // and answered with the same typed event; the connection resyncs
        // at the next newline.
        let huge = format!("{{\"text\":\"{}\"}}", "x".repeat(MAX_LINE_BYTES));
        let ev = round_trip(&huge);
        assert_eq!(ev.get("event").unwrap().as_str().unwrap(), "error");
        assert_eq!(ev.get("error").unwrap().as_str().unwrap(), "invalid");
        assert!(
            ev.get("message").unwrap().as_str().unwrap().contains("exceeds"),
            "oversized line should name the cap: {ev:?}"
        );

        // The connection is still usable: a valid request streams to a
        // finished event.
        conn.write_all(b"{\"op\":\"generate\",\"prompt\":[3,4,5],\"max_new_tokens\":2}\n")
            .unwrap();
        let mut kinds = Vec::new();
        loop {
            let mut out = String::new();
            assert!(reader.read_line(&mut out).unwrap() > 0, "closed mid-stream");
            let ev = Json::parse(out.trim()).unwrap();
            let kind = ev.get("event").unwrap().as_str().unwrap().to_string();
            assert_ne!(kind, "error", "valid request errored: {out}");
            kinds.push(kind.clone());
            if kind == "finished" {
                assert_eq!(ev.get("tokens").unwrap().as_arr().unwrap().len(), 2);
                break;
            }
        }
        assert_eq!(kinds.first().map(String::as_str), Some("admitted"));
        assert_eq!(kinds.iter().filter(|k| *k == "token").count(), 2);
        server.shutdown().unwrap();
    }

    #[test]
    fn event_lines_are_single_line_json_with_tag_echo() {
        let tag = json::num(42.0);
        let events = [
            StreamEvent::Admitted { id: 3 },
            StreamEvent::Token { id: 3, token: 104, pos: 0, ttft_hint: Some(0.002) },
            StreamEvent::Finished(RequestOutput {
                id: 3,
                adapter: Some("a".into()),
                tokens: vec![104, 105],
                finish: FinishReason::MaxTokens,
                ttft: 0.002,
                e2e: 0.01,
            }),
            StreamEvent::Error { id: 3, error: EngineError::DeadlineExceeded },
        ];
        for ev in &events {
            let line = event_json(ev, Some(&tag)).to_string_compact();
            assert!(!line.contains('\n'), "{line}");
            let back = Json::parse(&line).unwrap();
            assert_eq!(back.get("id").unwrap().as_usize().unwrap(), 3);
            assert_eq!(back.get("tag").unwrap().as_usize().unwrap(), 42);
        }
        let fin = event_json(&events[2], None);
        assert_eq!(fin.get("finish").unwrap().as_str().unwrap(), "max_tokens");
        assert_eq!(fin.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        let err = event_json(&events[3], None);
        assert_eq!(err.get("error").unwrap().as_str().unwrap(), "deadline_exceeded");
    }
}
