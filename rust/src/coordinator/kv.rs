//! KV-cache state and decode-slot allocation.
//!
//! XLA executables are shape-specialized, so the decode step runs at a
//! fixed slot count B; continuous batching assigns requests to free slot
//! lanes (each lane tracks its own sequence position — the per-slot `pos`
//! vector of the decode entry point).  The cache layout matches the HLO
//! signature: [n_layers, B, n_heads, max_seq, head_dim], f32.

use anyhow::{bail, Result};

use crate::manifest::ModelConfigInfo;
use crate::tensor::{DType, HostTensor};

/// Free-list slot allocator with double-free protection.
#[derive(Debug)]
pub struct SlotAllocator {
    free: Vec<usize>,
    in_use: Vec<bool>,
}

impl SlotAllocator {
    pub fn new(n: usize) -> SlotAllocator {
        SlotAllocator { free: (0..n).rev().collect(), in_use: vec![false; n] }
    }

    pub fn alloc(&mut self) -> Option<usize> {
        let s = self.free.pop()?;
        self.in_use[s] = true;
        Some(s)
    }

    pub fn release(&mut self, slot: usize) -> Result<()> {
        if slot >= self.in_use.len() {
            bail!("slot {slot} out of range");
        }
        if !self.in_use[slot] {
            bail!("double free of slot {slot}");
        }
        self.in_use[slot] = false;
        self.free.push(slot);
        Ok(())
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    pub fn n_total(&self) -> usize {
        self.in_use.len()
    }

    pub fn is_in_use(&self, slot: usize) -> bool {
        self.in_use.get(slot).copied().unwrap_or(false)
    }
}

/// Host-resident K/V caches for all decode slots.
pub struct KvState {
    pub k: HostTensor,
    pub v: HostTensor,
    pub n_layers: usize,
    pub n_slots: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub head_dim: usize,
}

impl KvState {
    pub fn new(cfg: &ModelConfigInfo, n_slots: usize) -> KvState {
        let shape = vec![cfg.n_layers, n_slots, cfg.n_heads, cfg.max_seq, cfg.head_dim];
        KvState {
            k: HostTensor::zeros(shape.clone(), DType::F32),
            v: HostTensor::zeros(shape, DType::F32),
            n_layers: cfg.n_layers,
            n_slots,
            n_heads: cfg.n_heads,
            max_seq: cfg.max_seq,
            head_dim: cfg.head_dim,
        }
    }

    /// Flat element offset of [layer, slot, head, 0, 0].
    fn lane_offset(&self, layer: usize, slot: usize, head: usize) -> usize {
        ((layer * self.n_slots + slot) * self.n_heads + head) * self.max_seq * self.head_dim
    }

    /// Copy one request's cache lane out of a prefill output
    /// ([n_layers, b_prefill, n_heads, max_seq, head_dim]) into `slot`.
    pub fn adopt_prefill_lane(
        &mut self,
        pk: &HostTensor,
        pv: &HostTensor,
        prefill_lane: usize,
        slot: usize,
        prompt_len: usize,
    ) -> Result<()> {
        let b_pre = pk.shape[1];
        if prefill_lane >= b_pre || slot >= self.n_slots {
            bail!("lane {prefill_lane}/{b_pre} or slot {slot}/{} out of range", self.n_slots);
        }
        // Only the first prompt_len positions carry data; copying the head
        // of each [max_seq, head_dim] row bounds the memcpy to what matters.
        let row = prompt_len.min(self.max_seq) * self.head_dim;
        for l in 0..self.n_layers {
            for h in 0..self.n_heads {
                let src =
                    ((l * b_pre + prefill_lane) * self.n_heads + h) * self.max_seq * self.head_dim;
                let dst = self.lane_offset(l, slot, h);
                let kd = pk.read_f32_range(src, row);
                self.k.write_f32_range(dst, &kd);
                let vd = pv.read_f32_range(src, row);
                self.v.write_f32_range(dst, &vd);
            }
        }
        Ok(())
    }

    /// Replace both caches with the decode step's outputs (same shape).
    pub fn replace(&mut self, k: HostTensor, v: HostTensor) -> Result<()> {
        if k.shape != self.k.shape || v.shape != self.v.shape {
            bail!("kv shape changed: {:?} vs {:?}", k.shape, self.k.shape);
        }
        self.k = k;
        self.v = v;
        Ok(())
    }

    /// Zero a slot's lanes (hygiene on release; correctness does not depend
    /// on it because prefill overwrites and masks exclude stale positions).
    pub fn clear_slot(&mut self, slot: usize) {
        let zeros = vec![0f32; self.max_seq * self.head_dim];
        for l in 0..self.n_layers {
            for h in 0..self.n_heads {
                let off = self.lane_offset(l, slot, h);
                self.k.write_f32_range(off, &zeros);
                self.v.write_f32_range(off, &zeros);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfigInfo {
        ModelConfigInfo {
            name: "t".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 12,
            max_seq: 8,
            head_dim: 4,
            n_adapters: 4,
            lora_rank: 2,
        }
    }

    #[test]
    fn alloc_release_cycle() {
        let mut a = SlotAllocator::new(3);
        let s1 = a.alloc().unwrap();
        let s2 = a.alloc().unwrap();
        assert_ne!(s1, s2);
        assert_eq!(a.n_free(), 1);
        a.release(s1).unwrap();
        assert!(a.release(s1).is_err(), "double free must fail");
        assert_eq!(a.n_free(), 2);
        let _ = a.alloc().unwrap();
        let _ = a.alloc().unwrap();
        assert!(a.alloc().is_none());
    }

    #[test]
    fn adopt_prefill_lane_copies_right_region() {
        let c = cfg();
        let mut kv = KvState::new(&c, 4);
        // prefill output with b=2; fill lane 1 with a marker pattern
        let shape = vec![c.n_layers, 2, c.n_heads, c.max_seq, c.head_dim];
        let n: usize = shape.iter().product();
        let mut pk = HostTensor::zeros(shape.clone(), DType::F32);
        let pv = HostTensor::zeros(shape, DType::F32);
        for l in 0..c.n_layers {
            for h in 0..c.n_heads {
                let off = ((l * 2 + 1) * c.n_heads + h) * c.max_seq * c.head_dim;
                pk.write_f32_range(off, &vec![7.5; 3 * c.head_dim]);
            }
        }
        assert!(n > 0);
        kv.adopt_prefill_lane(&pk, &pv, 1, 2, 3).unwrap();
        // slot 2 has the marker in the first 3 positions of every lane
        for l in 0..c.n_layers {
            for h in 0..c.n_heads {
                let off = kv.lane_offset(l, 2, h);
                assert_eq!(kv.k.read_f32_range(off, 3 * c.head_dim), vec![7.5; 3 * c.head_dim]);
                assert_eq!(kv.k.f32_at(off + 3 * c.head_dim), 0.0);
            }
        }
        // other slots untouched
        assert_eq!(kv.k.f32_at(kv.lane_offset(0, 1, 0)), 0.0);
    }

    #[test]
    fn clear_slot_zeroes() {
        let c = cfg();
        let mut kv = KvState::new(&c, 2);
        kv.k.write_f32_range(kv.lane_offset(0, 1, 0), &[9.0; 4]);
        kv.clear_slot(1);
        assert_eq!(kv.k.f32_at(kv.lane_offset(0, 1, 0)), 0.0);
    }
}
