//! Instruction-following suite (Table 5, AlpacaEval-2.0 analogue): byte-
//! level instruction templates whose execution is deterministic, scored by
//! an LL-judge (win = the finetuned model assigns lower NLL to the gold
//! response than the reference model does) instead of GPT-4.

use super::{Example, Metric, Task};
use crate::util::rng::Rng;

fn rand_word(rng: &mut Rng, n: usize) -> String {
    (0..n).map(|_| (b'a' + rng.below(16) as u8) as char).collect()
}

/// One instruction template.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// "rev:abc>" -> "cba."
    Reverse,
    /// "upp:abc>" -> "ABC."
    Upper,
    /// "dup:abc>" -> "aabbcc."
    Duplicate,
    /// "lst:abc>" -> "c." (last character)
    Last,
    /// "cnt:abc>" -> "3." (length as a digit)
    Count,
}

pub struct InstructX {
    pub kind: Kind,
}

impl InstructX {
    pub fn apply(kind: Kind, word: &str) -> String {
        let out = match kind {
            Kind::Reverse => word.chars().rev().collect::<String>(),
            Kind::Upper => word.to_uppercase(),
            Kind::Duplicate => word.chars().flat_map(|c| [c, c]).collect(),
            Kind::Last => word.chars().last().unwrap().to_string(),
            Kind::Count => word.chars().count().to_string(),
        };
        format!("{out}.")
    }

    fn tag(kind: Kind) -> &'static str {
        match kind {
            Kind::Reverse => "rev",
            Kind::Upper => "upp",
            Kind::Duplicate => "dup",
            Kind::Last => "lst",
            Kind::Count => "cnt",
        }
    }
}

impl Task for InstructX {
    fn name(&self) -> &'static str {
        match self.kind {
            Kind::Reverse => "instr-rev",
            Kind::Upper => "instr-upp",
            Kind::Duplicate => "instr-dup",
            Kind::Last => "instr-lst",
            Kind::Count => "instr-cnt",
        }
    }
    fn metric(&self) -> Metric {
        Metric::WinRate
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let n = 3 + rng.below(5); // 3..=7 chars
        let word = rand_word(rng, n);
        Example::gen(
            &format!("{}:{word}>", Self::tag(self.kind)),
            &Self::apply(self.kind, &word),
        )
    }
}

/// The five instruction tasks (the "10K cleaned Alpaca" analogue mixes all
/// of them during finetuning).
pub fn all() -> Vec<Box<dyn Task>> {
    vec![
        Box::new(InstructX { kind: Kind::Reverse }),
        Box::new(InstructX { kind: Kind::Upper }),
        Box::new(InstructX { kind: Kind::Duplicate }),
        Box::new(InstructX { kind: Kind::Last }),
        Box::new(InstructX { kind: Kind::Count }),
    ]
}

/// A second instruction distribution (the "UltraFeedback" analogue):
/// longer words, skewed template mix.
pub struct UltraX;

impl Task for UltraX {
    fn name(&self) -> &'static str {
        "instr-ultra"
    }
    fn metric(&self) -> Metric {
        Metric::WinRate
    }
    fn sample(&self, rng: &mut Rng) -> Example {
        let kind = match rng.weighted(&[3.0, 1.0, 1.0]) {
            0 => Kind::Reverse,
            1 => Kind::Last,
            _ => Kind::Count,
        };
        let n = 5 + rng.below(6);
        let word = rand_word(rng, n);
        Example::gen(
            &format!("{}:{word}>", InstructX::tag(kind)),
            &InstructX::apply(kind, &word),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn templates_execute_correctly() {
        assert_eq!(InstructX::apply(Kind::Reverse, "abc"), "cba.");
        assert_eq!(InstructX::apply(Kind::Upper, "abc"), "ABC.");
        assert_eq!(InstructX::apply(Kind::Duplicate, "ab"), "aabb.");
        assert_eq!(InstructX::apply(Kind::Last, "abc"), "c.");
        assert_eq!(InstructX::apply(Kind::Count, "abcd"), "4.");
    }

    #[test]
    fn samples_round_trip() {
        let mut rng = Rng::seed_from(77);
        let t = InstructX { kind: Kind::Reverse };
        for _ in 0..50 {
            let ex = t.sample(&mut rng);
            let p = crate::tokenizer::decode(&ex.prompt);
            let word = p.trim_start_matches("rev:").trim_end_matches('>');
            assert_eq!(
                crate::tokenizer::decode(&ex.completion),
                InstructX::apply(Kind::Reverse, word)
            );
        }
    }

    #[test]
    fn ultra_mix_varies_templates() {
        let mut rng = Rng::seed_from(78);
        let tags: std::collections::BTreeSet<String> = (0..100)
            .map(|_| {
                let ex = UltraX.sample(&mut rng);
                crate::tokenizer::decode(&ex.prompt)[..3].to_string()
            })
            .collect();
        assert!(tags.len() >= 2, "{tags:?}");
    }
}
