//! End-to-end numerics: HLO artifacts produced by python/compile/aot.py,
//! loaded and executed through the rust PJRT runtime, compared against the
//! golden records computed by jax at artifact-build time.

use road::runtime::{allclose, Runtime};

fn runtime() -> Runtime {
    Runtime::from_default_artifacts().expect("run `make artifacts` first")
}

#[test]
fn golden_decode_road() {
    let rt = runtime();
    let (ins, expected) = rt.load_golden("decode_road_tiny_b2").unwrap();
    let exe = rt.load("decode_road_tiny_b2").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    assert_eq!(outs.len(), expected.len());
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 1e-4, 1e-5).unwrap();
    }
}

#[test]
fn golden_decode_base() {
    let rt = runtime();
    let (ins, expected) = rt.load_golden("decode_base_tiny_b2").unwrap();
    let exe = rt.load("decode_base_tiny_b2").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 1e-4, 1e-5).unwrap();
    }
}

#[test]
fn golden_prefill_road() {
    let rt = runtime();
    let (ins, expected) = rt.load_golden("prefill_road_tiny_b2_l16").unwrap();
    let exe = rt.load("prefill_road_tiny_b2_l16").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 1e-4, 1e-5).unwrap();
    }
}

#[test]
fn golden_train_step_road1() {
    let rt = runtime();
    let (ins, expected) = rt.load_golden("train_road1_tiny").unwrap();
    let exe = rt.load("train_road1_tiny").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    // train outputs include the loss scalar as the last element
    let loss = outs.last().unwrap().as_f32()[0];
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 2e-3, 1e-4).unwrap();
    }
}

#[test]
fn golden_eval_loss_road1() {
    let rt = runtime();
    let (ins, expected) = rt.load_golden("eval_loss_road1_tiny").unwrap();
    let exe = rt.load("eval_loss_road1_tiny").unwrap();
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    let outs = exe.run_host(&refs).unwrap();
    for (o, e) in outs.iter().zip(&expected) {
        allclose(o, e, 1e-3, 1e-5).unwrap();
    }
}

#[test]
fn executable_rejects_wrong_arity_and_shape() {
    let rt = runtime();
    let exe = rt.load("decode_base_tiny_b2").unwrap();
    assert!(exe.run_host(&[]).is_err());
    let (mut ins, _) = rt.load_golden("decode_base_tiny_b2").unwrap();
    // corrupt a shape
    let bad = road::HostTensor::f32(vec![1], vec![0.0]);
    ins[0] = bad;
    let refs: Vec<&road::HostTensor> = ins.iter().collect();
    assert!(exe.run_host(&refs).is_err());
}

#[test]
fn manifest_loads_and_entries_consistent() {
    let rt = runtime();
    assert!(rt.manifest.entries.len() >= 90, "{}", rt.manifest.entries.len());
    for cfg in ["tiny", "serve", "train", "train2"] {
        assert!(rt.manifest.configs.contains_key(cfg));
    }
    // decode buckets advertised by the manifest exist as entries
    for b in &rt.manifest.serve_decode_batches {
        for mode in ["base", "road", "lora"] {
            let name = format!("decode_{mode}_serve_b{b}");
            assert!(rt.manifest.entries.contains_key(&name), "{name}");
        }
    }
}
