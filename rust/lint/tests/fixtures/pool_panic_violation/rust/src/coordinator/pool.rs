pub fn alloc_private(free: &mut Vec<usize>) -> usize {
    free.pop().unwrap()
}

pub fn ref_cached(block: Option<usize>) -> usize {
    block.expect("key published")
}

pub fn release_private(held: &[bool], block: usize) {
    if !held[block] {
        panic!("double release of block {block}");
    }
}

pub fn conservation(n: usize, free: usize) {
    if free > n {
        unreachable!("free list larger than the pool");
    }
}

pub fn guarded_refcount(m: &std::sync::Mutex<usize>) -> usize {
    *m.lock().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_unwrap() {
        Some(1usize).unwrap();
    }
}
