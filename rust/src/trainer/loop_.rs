//! The training loop: recipe-driven iteration over a batch source, with
//! periodic eval and a recorded loss curve.

use anyhow::Result;

use super::{Recipe, TrainBatch, Trainer};
use crate::util::rng::Rng;

/// A source of training batches; implemented by the synthetic task suites
/// ([`crate::tasks`]).
pub trait BatchSource {
    /// Produce one [B, L]-shaped batch (shapes fixed by the trainer).
    fn next_batch(&mut self, rng: &mut Rng) -> TrainBatch;
}

impl<F: FnMut(&mut Rng) -> TrainBatch> BatchSource for F {
    fn next_batch(&mut self, rng: &mut Rng) -> TrainBatch {
        self(rng)
    }
}

/// Summary of one training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub method: String,
    pub n_trainable: usize,
    pub steps: usize,
    pub first_loss: f32,
    pub final_loss: f32,
    /// Mean of the last 10% of per-step losses (noise-robust endpoint).
    pub tail_loss: f32,
    pub losses: Vec<f32>,
    pub wall_secs: f64,
    pub step_secs: f64,
    /// Periodic eval losses as (step, mean NLL), if eval_every > 0.
    pub eval_curve: Vec<(usize, f32)>,
}

impl TrainReport {
    pub fn summary_line(&self) -> String {
        format!(
            "{:<14} #train={:<8} steps={:<5} loss {:.4} -> {:.4} (tail {:.4})  {:.2}s",
            self.method, self.n_trainable, self.steps, self.first_loss, self.final_loss,
            self.tail_loss, self.wall_secs
        )
    }
}

/// Run `recipe.steps` optimizer steps pulling batches from `source`.
///
/// `eval_source` (when given, with `recipe.eval_every > 0`) is sampled for
/// a held-out batch at each eval point — the validation split protocol of
/// paper §C.1.
pub fn train(
    trainer: &mut Trainer,
    recipe: &Recipe,
    source: &mut dyn BatchSource,
    mut eval_source: Option<&mut dyn BatchSource>,
) -> Result<TrainReport> {
    let mut rng = Rng::seed_from(recipe.seed);
    let mut eval_rng = Rng::seed_from(recipe.seed ^ 0x5eed_e7a1);
    // roadlint: allow(clock-discipline) -- wall-profiles the real training
    // run for the report; training has no virtual-time mode.
    let t0 = std::time::Instant::now();
    let mut eval_curve = Vec::new();
    let step_t0 = trainer.step_time;
    let base_step = trainer.steps_done;

    for i in 0..recipe.steps {
        let batch = source.next_batch(&mut rng);
        let lr = recipe.lr_at(i);
        let loss = trainer.step(&batch, lr)?;
        if recipe.log_every > 0 && (i + 1) % recipe.log_every == 0 {
            println!(
                "  [{}] step {:>5}/{} lr={:.2e} loss={:.4}",
                trainer.method,
                i + 1,
                recipe.steps,
                lr,
                loss
            );
        }
        if recipe.eval_every > 0 && (i + 1) % recipe.eval_every == 0 {
            if let Some(src) = eval_source.as_deref_mut() {
                let eb = src.next_batch(&mut eval_rng);
                let (_, nll) = trainer.eval_loss(&eb)?;
                eval_curve.push((i + 1, nll));
            }
        }
    }

    let losses: Vec<f32> =
        trainer.loss_history[base_step.min(trainer.loss_history.len())..].to_vec();
    let tail_n = (losses.len() / 10).max(1).min(losses.len().max(1));
    let tail_loss = if losses.is_empty() {
        f32::NAN
    } else {
        losses[losses.len() - tail_n..].iter().sum::<f32>() / tail_n as f32
    };
    Ok(TrainReport {
        method: trainer.method.clone(),
        n_trainable: trainer.n_trainable,
        steps: recipe.steps,
        first_loss: losses.first().copied().unwrap_or(f32::NAN),
        final_loss: losses.last().copied().unwrap_or(f32::NAN),
        tail_loss,
        losses,
        wall_secs: t0.elapsed().as_secs_f64(),
        step_secs: (trainer.step_time - step_t0).as_secs_f64(),
        eval_curve,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closure_is_a_batch_source() {
        let mut calls = 0usize;
        {
            let mut src = |_rng: &mut Rng| {
                calls += 1;
                TrainBatch::zeros(1, 2)
            };
            let mut r = Rng::seed_from(0);
            let b = BatchSource::next_batch(&mut src, &mut r);
            assert_eq!(b.tokens.len(), 2);
        }
        assert_eq!(calls, 1);
    }
}
