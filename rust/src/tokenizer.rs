//! Byte-level tokenizer over the model's 256-token vocabulary.
//!
//! Token 0 is reserved as PAD/EOS; task generators avoid emitting it inside
//! payloads.  This mirrors the vocab=256 presets in python/compile/configs.

pub const PAD: i32 = 0;
pub const EOS: i32 = 0;

pub fn encode(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b.max(1) as i32).collect()
}

pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .take_while(|&&t| t != EOS)
        .map(|&t| (t.clamp(0, 255)) as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Truncate/pad to a fixed length (right padding with PAD).
pub fn pad_to(tokens: &[i32], len: usize) -> Vec<i32> {
    let mut out = tokens.to_vec();
    out.truncate(len);
    while out.len() < len {
        out.push(PAD);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = encode("hello, road!");
        assert_eq!(decode(&t), "hello, road!");
    }

    #[test]
    fn eos_terminates_decode() {
        assert_eq!(decode(&[104, 105, EOS, 120]), "hi");
    }

    #[test]
    fn pad_to_len() {
        assert_eq!(pad_to(&[1, 2], 4), vec![1, 2, 0, 0]);
        assert_eq!(pad_to(&[1, 2, 3], 2), vec![1, 2]);
    }
}
