pub enum EngineError {
    QueueFull,
    Mystery,
}

impl EngineError {
    pub fn kind(&self) -> &'static str {
        match self {
            EngineError::QueueFull => "queue_full",
            EngineError::Mystery => "mystery_kind",
        }
    }
}
