//! KV-cache state and decode-slot allocation.
//!
//! XLA executables are shape-specialized, so the decode step runs at a
//! fixed slot count B; continuous batching assigns requests to free slot
//! lanes (each lane tracks its own sequence position — the per-slot `pos`
//! vector of the decode entry point).  The cache layout matches the HLO
//! signature: [n_layers, B, n_heads, max_seq, head_dim], f32.
//!
//! # Residency
//!
//! [`KvState`] is a two-residency cache: exactly one of the host tensors or
//! the device buffers is authoritative at any time.
//!
//! * **Device** is the steady state of the decode loop: step `t`'s output
//!   buffers are installed via [`KvState::install_device`] and fed straight
//!   back in at step `t+1` ([`KvState::device_pair`]) with no host copy.
//! * **Host** is the escape hatch: [`KvState::materialize_host`] downloads
//!   the cache for operations PJRT has no artifact for — prefill lane
//!   adoption ([`KvState::adopt_prefill_lane`]), slot clearing, tests, and
//!   golden-record comparison.  Prefill admission therefore costs one full
//!   cache round-trip *per admitted batch*; the per-step decode transfers
//!   stay O(B·vocab) (logits only).

use anyhow::{bail, Result};

use crate::manifest::ModelConfigInfo;
use crate::runtime::{buffer_to_host, upload};
use crate::tensor::{DType, HostTensor};

/// Free-list slot allocator with double-free protection.
#[derive(Debug)]
pub struct SlotAllocator {
    free: Vec<usize>,
    in_use: Vec<bool>,
}

impl SlotAllocator {
    pub fn new(n: usize) -> SlotAllocator {
        SlotAllocator { free: (0..n).rev().collect(), in_use: vec![false; n] }
    }

    pub fn alloc(&mut self) -> Option<usize> {
        let s = self.free.pop()?;
        self.in_use[s] = true;
        Some(s)
    }

    pub fn release(&mut self, slot: usize) -> Result<()> {
        if slot >= self.in_use.len() {
            bail!("slot {slot} out of range");
        }
        if !self.in_use[slot] {
            bail!("double free of slot {slot}");
        }
        self.in_use[slot] = false;
        self.free.push(slot);
        Ok(())
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    pub fn n_total(&self) -> usize {
        self.in_use.len()
    }

    pub fn is_in_use(&self, slot: usize) -> bool {
        self.in_use.get(slot).copied().unwrap_or(false)
    }
}

/// Which side of the host/device boundary currently owns the cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    Host,
    Device,
}

/// K/V caches for all decode slots (see module docs for the residency
/// model).
pub struct KvState {
    /// Host-side tensors; authoritative only when `residency == Host`.
    hk: HostTensor,
    hv: HostTensor,
    /// Device-side buffers; `Some` exactly when `residency == Device`.
    dev: Option<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    residency: Residency,
    pub n_layers: usize,
    pub n_slots: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub head_dim: usize,
}

impl KvState {
    pub fn new(cfg: &ModelConfigInfo, n_slots: usize) -> KvState {
        let shape = vec![cfg.n_layers, n_slots, cfg.n_heads, cfg.max_seq, cfg.head_dim];
        KvState {
            hk: HostTensor::zeros(shape.clone(), DType::F32),
            hv: HostTensor::zeros(shape, DType::F32),
            dev: None,
            residency: Residency::Host,
            n_layers: cfg.n_layers,
            n_slots,
            n_heads: cfg.n_heads,
            max_seq: cfg.max_seq,
            head_dim: cfg.head_dim,
        }
    }

    pub fn shape(&self) -> Vec<usize> {
        vec![self.n_layers, self.n_slots, self.n_heads, self.max_seq, self.head_dim]
    }

    pub fn residency(&self) -> Residency {
        self.residency
    }

    /// Host-materialization escape hatch: download the cache if it is
    /// device-resident.  Returns `true` when a transfer actually happened.
    ///
    /// Downloads complete before any state is committed, so a failed
    /// transfer leaves the cache device-resident and retryable rather than
    /// wedged between residencies.
    pub fn materialize_host(&mut self) -> Result<bool> {
        let Some((kb, vb)) = self.dev.as_ref() else {
            return Ok(false);
        };
        let k = buffer_to_host(kb, DType::F32)?;
        let v = buffer_to_host(vb, DType::F32)?;
        let want = self.shape();
        if k.shape != want || v.shape != want {
            bail!("device cache shape {:?}/{:?}, expected {:?}", k.shape, v.shape, want);
        }
        self.dev = None;
        self.hk = k;
        self.hv = v;
        self.residency = Residency::Host;
        Ok(true)
    }

    /// Upload the cache if it is host-resident.  Returns `true` when a
    /// transfer actually happened.
    ///
    /// The host tensors are released after the upload — they are stale
    /// while device-resident, and at serve size they are the largest host
    /// allocation; `materialize_host` reallocates them from the download.
    pub fn ensure_device(&mut self, client: &xla::PjRtClient) -> Result<bool> {
        if self.residency == Residency::Device {
            return Ok(false);
        }
        let kb = upload(client, &self.hk)?;
        let vb = upload(client, &self.hv)?;
        self.hk = HostTensor::zeros(vec![0], DType::F32);
        self.hv = HostTensor::zeros(vec![0], DType::F32);
        self.dev = Some((kb, vb));
        self.residency = Residency::Device;
        Ok(true)
    }

    /// The device buffers to pass as the decode step's `k_cache`/`v_cache`
    /// inputs.  Call [`KvState::ensure_device`] first.
    pub fn device_pair(&self) -> Result<(&xla::PjRtBuffer, &xla::PjRtBuffer)> {
        match &self.dev {
            Some((k, v)) => Ok((k, v)),
            None => bail!("KV cache is host-resident; call ensure_device first"),
        }
    }

    /// Install a decode step's output buffers as the new cache (the
    /// zero-copy hand-off that keeps the loop device-resident).
    pub fn install_device(&mut self, k: xla::PjRtBuffer, v: xla::PjRtBuffer) -> Result<()> {
        let want: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        if k.dims() != want || v.dims() != want {
            bail!(
                "decode returned cache dims {:?}/{:?}, expected {:?}",
                k.dims(),
                v.dims(),
                want
            );
        }
        self.dev = Some((k, v));
        self.residency = Residency::Device;
        Ok(())
    }

    /// Host view of the K cache (host residency required).
    pub fn host_k(&self) -> Result<&HostTensor> {
        match self.residency {
            Residency::Host => Ok(&self.hk),
            Residency::Device => bail!("KV cache is device-resident; materialize_host first"),
        }
    }

    /// Host view of the V cache (host residency required).
    pub fn host_v(&self) -> Result<&HostTensor> {
        match self.residency {
            Residency::Host => Ok(&self.hv),
            Residency::Device => bail!("KV cache is device-resident; materialize_host first"),
        }
    }

    /// Flat element offset of [layer, slot, head, 0, 0].
    fn lane_offset(&self, layer: usize, slot: usize, head: usize) -> usize {
        ((layer * self.n_slots + slot) * self.n_heads + head) * self.max_seq * self.head_dim
    }

    /// Copy one request's cache lane out of a prefill output
    /// ([n_layers, b_prefill, n_heads, max_seq, head_dim]) into `slot`.
    /// Materializes the cache to host if needed (the admission-time escape
    /// hatch; see module docs).
    pub fn adopt_prefill_lane(
        &mut self,
        pk: &HostTensor,
        pv: &HostTensor,
        prefill_lane: usize,
        slot: usize,
        prompt_len: usize,
    ) -> Result<()> {
        self.materialize_host()?;
        let b_pre = pk.shape[1];
        if prefill_lane >= b_pre || slot >= self.n_slots {
            bail!("lane {prefill_lane}/{b_pre} or slot {slot}/{} out of range", self.n_slots);
        }
        // Only the first prompt_len positions carry data; copying the head
        // of each [max_seq, head_dim] row bounds the memcpy to what matters.
        let row = prompt_len.min(self.max_seq) * self.head_dim;
        for l in 0..self.n_layers {
            for h in 0..self.n_heads {
                let src =
                    ((l * b_pre + prefill_lane) * self.n_heads + h) * self.max_seq * self.head_dim;
                let dst = self.lane_offset(l, slot, h);
                let kd = pk.read_f32_range(src, row);
                self.hk.write_f32_range(dst, &kd);
                let vd = pv.read_f32_range(src, row);
                self.hv.write_f32_range(dst, &vd);
            }
        }
        Ok(())
    }

    /// Replace both caches with host tensors (the host-round-trip baseline
    /// path; the device-resident loop uses [`KvState::install_device`]).
    pub fn replace(&mut self, k: HostTensor, v: HostTensor) -> Result<()> {
        let want = self.shape();
        if k.shape != want || v.shape != want {
            bail!("kv shape changed: {:?} vs {:?}", k.shape, want);
        }
        self.hk = k;
        self.hv = v;
        self.dev = None;
        self.residency = Residency::Host;
        Ok(())
    }

    /// Zero a slot's lanes (hygiene on release; correctness does not depend
    /// on it because prefill overwrites and masks exclude stale positions).
    pub fn clear_slot(&mut self, slot: usize) -> Result<()> {
        self.materialize_host()?;
        let zeros = vec![0f32; self.max_seq * self.head_dim];
        for l in 0..self.n_layers {
            for h in 0..self.n_heads {
                let off = self.lane_offset(l, slot, h);
                self.hk.write_f32_range(off, &zeros);
                self.hv.write_f32_range(off, &zeros);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfigInfo {
        ModelConfigInfo {
            name: "t".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 12,
            max_seq: 8,
            head_dim: 4,
            n_adapters: 4,
            lora_rank: 2,
        }
    }

    #[test]
    fn alloc_release_cycle() {
        let mut a = SlotAllocator::new(3);
        let s1 = a.alloc().unwrap();
        let s2 = a.alloc().unwrap();
        assert_ne!(s1, s2);
        assert_eq!(a.n_free(), 1);
        a.release(s1).unwrap();
        assert!(a.release(s1).is_err(), "double free must fail");
        assert_eq!(a.n_free(), 2);
        let _ = a.alloc().unwrap();
        let _ = a.alloc().unwrap();
        assert!(a.alloc().is_none());
    }

    #[test]
    fn adopt_prefill_lane_copies_right_region() {
        let c = cfg();
        let mut kv = KvState::new(&c, 4);
        // prefill output with b=2; fill lane 1 with a marker pattern
        let shape = vec![c.n_layers, 2, c.n_heads, c.max_seq, c.head_dim];
        let n: usize = shape.iter().product();
        let mut pk = HostTensor::zeros(shape.clone(), DType::F32);
        let pv = HostTensor::zeros(shape, DType::F32);
        for l in 0..c.n_layers {
            for h in 0..c.n_heads {
                let off = ((l * 2 + 1) * c.n_heads + h) * c.max_seq * c.head_dim;
                pk.write_f32_range(off, &vec![7.5; 3 * c.head_dim]);
            }
        }
        assert!(n > 0);
        kv.adopt_prefill_lane(&pk, &pv, 1, 2, 3).unwrap();
        // slot 2 has the marker in the first 3 positions of every lane
        let hk = kv.host_k().unwrap().clone();
        for l in 0..c.n_layers {
            for h in 0..c.n_heads {
                let off = kv.lane_offset(l, 2, h);
                assert_eq!(hk.read_f32_range(off, 3 * c.head_dim), vec![7.5; 3 * c.head_dim]);
                assert_eq!(hk.f32_at(off + 3 * c.head_dim), 0.0);
            }
        }
        // other slots untouched
        assert_eq!(hk.f32_at(kv.lane_offset(0, 1, 0)), 0.0);
    }

    #[test]
    fn clear_slot_zeroes() {
        let c = cfg();
        let mut kv = KvState::new(&c, 2);
        let off = kv.lane_offset(0, 1, 0);
        kv.hk.write_f32_range(off, &[9.0; 4]);
        kv.clear_slot(1).unwrap();
        assert_eq!(kv.host_k().unwrap().f32_at(off), 0.0);
    }

    #[test]
    fn device_roundtrip_preserves_cache() {
        let c = cfg();
        let client = xla::PjRtClient::cpu().unwrap();
        let mut kv = KvState::new(&c, 2);
        let marker = kv.lane_offset(1, 1, 1);
        kv.hk.write_f32_range(marker, &[3.25; 4]);
        kv.hv.write_f32_range(marker, &[-1.5; 4]);

        assert_eq!(kv.residency(), Residency::Host);
        assert!(kv.ensure_device(&client).unwrap(), "first upload transfers");
        assert_eq!(kv.residency(), Residency::Device);
        assert!(!kv.ensure_device(&client).unwrap(), "already device-resident");
        assert!(kv.host_k().is_err(), "host view requires materialization");
        kv.device_pair().unwrap();

        assert!(kv.materialize_host().unwrap(), "download transfers");
        assert!(!kv.materialize_host().unwrap(), "already host-resident");
        assert_eq!(kv.host_k().unwrap().read_f32_range(marker, 4), vec![3.25; 4]);
        assert_eq!(kv.host_v().unwrap().read_f32_range(marker, 4), vec![-1.5; 4]);
        assert!(kv.device_pair().is_err());
    }

    #[test]
    fn install_device_swaps_in_decode_outputs() {
        let c = cfg();
        let client = xla::PjRtClient::cpu().unwrap();
        let mut kv = KvState::new(&c, 2);
        let shape = kv.shape();
        let n: usize = shape.iter().product();
        // Pretend these are the decode step's k/v output buffers.
        let k_new = HostTensor::f32(shape.clone(), vec![2.0; n]);
        let v_new = HostTensor::f32(shape.clone(), vec![4.0; n]);
        let kb = upload(&client, &k_new).unwrap();
        let vb = upload(&client, &v_new).unwrap();
        kv.install_device(kb, vb).unwrap();
        assert_eq!(kv.residency(), Residency::Device);

        kv.materialize_host().unwrap();
        assert_eq!(kv.host_k().unwrap().f32_at(n - 1), 2.0);
        assert_eq!(kv.host_v().unwrap().f32_at(0), 4.0);

        // Shape mismatches are rejected.
        let bad = upload(&client, &HostTensor::f32(vec![2], vec![0.0, 1.0])).unwrap();
        let ok = upload(&client, &k_new).unwrap();
        assert!(kv.install_device(bad, ok).is_err());
    }

    #[test]
    fn adopt_materializes_device_cache_first() {
        let c = cfg();
        let client = xla::PjRtClient::cpu().unwrap();
        let mut kv = KvState::new(&c, 2);
        kv.ensure_device(&client).unwrap();

        let shape = vec![c.n_layers, 1, c.n_heads, c.max_seq, c.head_dim];
        let n: usize = shape.iter().product();
        let pk = HostTensor::f32(shape.clone(), vec![1.25; n]);
        let pv = HostTensor::f32(shape, vec![0.5; n]);
        kv.adopt_prefill_lane(&pk, &pv, 0, 1, 2).unwrap();

        assert_eq!(kv.residency(), Residency::Host, "adoption is a host operation");
        let off = kv.lane_offset(0, 1, 0);
        assert_eq!(kv.host_k().unwrap().read_f32_range(off, 2 * c.head_dim), vec![
            1.25;
            2 * c.head_dim
        ]);
    }
}
