//! `road` — the launcher for the RoAd reproduction stack.
//!
//! Subcommands (one per deliverable; see README.md):
//!
//! ```text
//! road serve       [--mode road|lora|base] [--slots 8] [--requests 32]
//!                  [--distinct 8] [--tokens 64] [--host-roundtrip-kv=true]
//!                  [--bank-slots N] [--whole-bank-uploads=true] [--stats=true]
//!                  [--queue-capacity 4096] [--policy fcfs|edf|priority|fair]
//!                  [--prefill-chunk 0]
//!                  [--backend pjrt|ref] [--listen 127.0.0.1:7433]
//!                  [--replicas 1] [--place affinity|least-loaded|round-robin]
//! road train       --method road1 [--suite nlu|commonsense|arithmetic]
//!                  [--steps 200] [--seed 0]
//! road exp         --suite nlu|commonsense|arithmetic|instruct|multimodal|
//!                  commonsense2|all [--steps 200] [--seeds 3] [--n-eval 256]
//! road pilot       --study magnitude-angle|disentangle [--steps 100]
//! road compose     [--steps 200] [--n-eval 32]
//! road bench-serving          --study merge|tokens|hetero|kv|bank|stream|sched|kvpage|router|adapters
//!                  [--tokens 64] [--adapters 64] [--bank-slots 4]
//!                  [--cancel-after 16] [--sim-clock] [--replicas 3]
//! road bench-train-efficiency [--iters 50]
//! road verify      (golden-record numerics check)
//! ```
//!
//! Experiment outputs are printed and appended to `results/<name>.md`.

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use road::bench;
use road::compose;
use road::coordinator::engine::{Engine, EngineConfig};
use road::exp::{self, ExpOptions};
use road::pilot;
use road::runtime::Runtime;
use road::tasks;
use road::trainer::{self, Recipe, Trainer};
use road::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("serve") => cmd_serve(args),
        Some("pretrain") => cmd_pretrain(args),
        Some("train") => cmd_train(args),
        Some("exp") => cmd_exp(args),
        Some("pilot") => cmd_pilot(args),
        Some("compose") => cmd_compose(args),
        Some("bench-serving") => cmd_bench_serving(args),
        Some("bench-train-efficiency") => cmd_bench_train(args),
        Some("verify") => cmd_verify(),
        Some(other) => bail!("unknown command {other:?} (try: serve pretrain train exp pilot compose bench-serving bench-train-efficiency verify)"),
        None => {
            println!("road — 2D Rotary Adaptation serving + finetuning stack");
            println!("usage: road <serve|train|exp|pilot|compose|bench-serving|bench-train-efficiency|verify> [--flags]");
            Ok(())
        }
    }
}

fn runtime() -> Result<Rc<Runtime>> {
    Ok(Rc::new(Runtime::from_default_artifacts().context(
        "loading artifacts (run `make artifacts` first, or set ROAD_ARTIFACTS)",
    )?))
}

/// Runtime for a serving command: `--backend ref` gets the artifact-free
/// pure-Rust reference model, `pjrt` (the default) the compiled artifacts.
fn runtime_for(backend: road::runtime::BackendKind) -> Result<Rc<Runtime>> {
    Ok(Rc::new(
        Runtime::for_backend(backend, road::Manifest::default_dir()).context(
            "loading artifacts (run `make artifacts` first, set ROAD_ARTIFACTS, or use --backend ref)",
        )?,
    ))
}

fn backend_flag(args: &Args) -> Result<road::runtime::BackendKind> {
    road::runtime::BackendKind::from_name(&args.get_or("backend", "pjrt"))
}

fn save_result(name: &str, content: &str) -> Result<()> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{name}.md");
    std::fs::write(&path, content)?;
    println!("\n[saved {path}]");
    Ok(())
}

// ---------------------------------------------------------------------------

fn serve_config(args: &Args, mode: &str, slots: usize) -> Result<EngineConfig> {
    Ok(EngineConfig {
        model: args.get_or("model", "serve"),
        mode: mode.to_string(),
        decode_slots: slots,
        // --queue-capacity bounds admission (typed QueueFull backpressure
        // past it), like the other knobs instead of a hardcoded constant.
        queue_capacity: args.usize_or("queue-capacity", 4096),
        // Diagnostic baseline: --host-roundtrip-kv=true restores the
        // pre-device-resident full-cache transfer on every decode step.
        kv_host_roundtrip: args.bool("host-roundtrip-kv"),
        // --bank-slots caps the pageable device bank below the artifact's
        // slot count (adapters beyond it page through LRU slots).
        bank_slots: args.get("bank-slots").and_then(|s| s.parse().ok()),
        // --whole-bank-uploads=true restores the re-upload-everything
        // baseline that paged per-slot uploads replace.
        paged_bank_uploads: !args.bool("whole-bank-uploads"),
        // --policy picks the admission scheduler: fcfs (default), edf,
        // priority, or fair (fair-share across adapters).
        policy: road::coordinator::sched::PolicyKind::from_name(&args.get_or("policy", "fcfs"))?,
        // --backend ref serves the pure-Rust reference model (no
        // artifacts); pjrt (default) serves the compiled HLO artifacts.
        backend: backend_flag(args)?,
        // --paged-kv=false restores the flat contiguous KV baseline (every
        // lane charges a full max_seq footprint; no prefix sharing).
        paged_kv: args.get("paged-kv").map_or(true, |v| matches!(v, "true" | "1" | "yes")),
        // --kv-block sets the tokens-per-block sharing granularity.
        kv_block_size: args.usize_or("kv-block", 16),
        // --kv-pool-blocks caps the shared block pool (the serving memory
        // budget; default sizes it so the gate never binds).
        kv_pool_blocks: args.get("kv-pool-blocks").and_then(|s| s.parse().ok()),
        // --prefill-chunk enables mixed steps: each iteration advances
        // every decode lane one token and spends the rest of this budget
        // feeding admitted prefills in chunks (0 = atomic prefill).
        prefill_chunk_tokens: args.usize_or("prefill-chunk", 0),
        // --fused-epilogue=false drops the reference backend to the scalar
        // adapter-epilogue oracle (same tokens; exists to prove it).
        fused_epilogue: args
            .get("fused-epilogue")
            .map_or(true, |v| matches!(v, "true" | "1" | "yes")),
        ..Default::default()
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mode = args.get_or("mode", "road");
    let slots = args.usize_or("slots", 8);
    let distinct = args.usize_or("distinct", if mode == "base" { 0 } else { 8 });
    let econf = serve_config(args, &mode, slots)?;

    // --listen switches from the self-driving bench workload to the real
    // front door: an NDJSON-over-TCP server over the streaming client API
    // (--replicas N puts a placement router in front of N engines).
    if let Some(addr) = args.get("listen") {
        let replicas = args.usize_or("replicas", 1);
        let place =
            road::coordinator::PlaceKind::from_name(&args.get_or("place", "affinity"))?;
        return cmd_serve_listen(addr, econf, distinct, replicas, place);
    }

    let n_requests = args.usize_or("requests", 32);
    let tokens = args.usize_or("tokens", 64);
    let rt = runtime_for(econf.backend)?;
    let mut engine = Engine::new(rt, econf)?;
    if distinct > 0 {
        bench::register_adapters(&mut engine, distinct, 7)?;
        println!("registered {distinct} {mode} adapters");
    }
    let mut rng = road::util::rng::Rng::seed_from(42);
    let reqs = bench::hetero_workload(&mut rng, n_requests, distinct, 8, tokens);
    println!(
        "serving {n_requests} heterogeneous requests ({} distinct adapters, {tokens} new tokens each, {slots} decode slots)...",
        distinct
    );
    // roadlint: allow(clock-discipline) -- CLI throughput printout wants
    // real elapsed time as the user experienced it.
    let t0 = std::time::Instant::now();
    let outs = engine.run_all(reqs)?;
    let wall = t0.elapsed().as_secs_f64();
    let gen: usize = outs.iter().map(|o| o.tokens.len()).sum();
    println!("{}", engine.metrics.report());
    if args.bool("stats") {
        // Full metric table, including the bank paging counters.
        println!("\n{}", engine.metrics.report_table());
    }
    println!(
        "completed {} requests, {gen} tokens in {wall:.2}s  ->  {:.1} tok/s",
        outs.len(),
        gen as f64 / wall
    );
    Ok(())
}

/// `road serve --listen <addr>`: a fleet of `--replicas` engines (each on
/// its own named thread with its own runtime and bank) behind a placement
/// [`road::coordinator::Router`], NDJSON front door on a TCP listener.
/// `--listen 127.0.0.1:0` picks a free port; the chosen address is
/// printed as `listening on <addr>` before the accept loop starts
/// (scripts/serve_smoke.py parses that line).
fn cmd_serve_listen(
    addr: &str,
    econf: EngineConfig,
    distinct: usize,
    replicas: usize,
    place: road::coordinator::PlaceKind,
) -> Result<()> {
    let mode = econf.mode.clone();
    // The setup closure runs on every replica's engine thread (Fn + Clone).
    let (fleet, router) = road::coordinator::Fleet::start(
        econf,
        road::Manifest::default_dir(),
        replicas,
        place,
        move |eng: &mut road::coordinator::Engine| {
            if distinct > 0 {
                bench::register_adapters(eng, distinct, 7)?;
                println!("registered {distinct} {mode} adapters");
            }
            Ok(())
        },
    )?;
    // The setup closure registered adapters engine-side on every replica;
    // record their home placements so affinity has homes to route to.
    for i in 0..distinct {
        router.place_adapter(&format!("adapter-{i}"));
    }
    println!("fleet up: {replicas} replica(s), placement {}", place.name());
    let listener = std::net::TcpListener::bind(addr)
        .with_context(|| format!("binding NDJSON listener on {addr}"))?;
    println!("listening on {}", listener.local_addr()?);
    let result = road::coordinator::net::serve(listener, router);
    fleet.shutdown()?;
    result
}

/// Full-finetune the random-init backbone on the generic pretraining
/// corpus and save `artifacts/pretrained_<cfg>.bin` — the starting point
/// every PEFT experiment adapts from (the paper's "pretrained LLM").
fn cmd_pretrain(args: &Args) -> Result<()> {
    let config = args.get_or("model", "train");
    let steps = args.usize_or("steps", 1500);
    let seed = args.usize_or("seed", 0) as u64;
    let rt = runtime()?;
    let out = rt.manifest.artifact_path(&format!("pretrained_{config}.bin"));
    if out.exists() && !args.bool("force") {
        println!("{} already exists (use --force=true to redo)", out.display());
        return Ok(());
    }
    let mut tr = Trainer::new(rt.clone(), &config, "full")?;
    let corpus = tasks::pretrain_corpus();
    let recipe = Recipe {
        lr: args.f64_or("lr", 1e-3) as f32,
        steps,
        warmup_ratio: 0.1,
        seed,
        eval_every: 0,
        log_every: args.usize_or("log-every", (steps / 10).max(1)),
    };
    println!("pretraining backbone {config} on the generic corpus ({steps} steps)...");
    let mut src = tasks::SuiteSampler::new(&corpus, tr.batch, tr.seq_len);
    let report = trainer::train(&mut tr, &recipe, &mut src, None)?;
    println!("{}", report.summary_line());
    tr.merged_params()?.save(&out)?;
    println!("saved pretrained backbone to {}", out.display());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let method = args.get_or("method", "road1");
    let suite_name = args.get_or("suite", "commonsense");
    let steps = args.usize_or("steps", 200);
    let seed = args.usize_or("seed", 0) as u64;
    let config = args.get_or("model", "train");

    let rt = runtime()?;
    let mut tr = Trainer::new(rt.clone(), &config, &method)?;
    println!(
        "training {method} on {suite_name} suite: {} trainable params ({:.3}% of backbone), {steps} steps",
        tr.n_trainable,
        100.0 * tr.n_trainable as f64
            / road::model::ParamStore::load(&rt.manifest, &config)?.n_params() as f64
    );
    let suite = match suite_name.as_str() {
        "nlu" => tasks::nlu_suite(),
        "commonsense" => tasks::commonsense_suite(),
        "arithmetic" => tasks::arithmetic_train_suite(),
        "instruct" => tasks::instruct_suite(),
        "multimodal" => tasks::multimodal_suite(),
        s => bail!("unknown suite {s}"),
    };
    let recipe = Recipe {
        lr: args.f64_or("lr", Recipe::default_lr(&method) as f64) as f32,
        steps,
        warmup_ratio: 0.1,
        seed,
        eval_every: 0,
        log_every: args.usize_or("log-every", (steps / 10).max(1)),
    };
    let mut src = tasks::SuiteSampler::new(&suite, tr.batch, tr.seq_len);
    let report = trainer::train(&mut tr, &recipe, &mut src, None)?;
    println!("{}", report.summary_line());
    if let Some(out) = args.get("save") {
        tr.save_trainable(out)?;
        println!("saved trainables to {out}");
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let suite = args.get_or("suite", "all");
    let opts = ExpOptions {
        steps: args.usize_or("steps", 200),
        seeds: (0..args.usize_or("seeds", 3) as u64).collect(),
        n_eval: args.usize_or("n-eval", 256),
        verbose: args.bool("verbose"),
    };
    let rt = runtime()?;
    let mut fig1: Vec<(String, Vec<exp::MethodRow>)> = Vec::new();

    if suite == "nlu" || suite == "all" {
        println!("== Table 2 analogue: NLU ({} methods x 8 tasks x {} seeds, {} steps) ==",
            exp::NLU_METHODS.len(), opts.seeds.len(), opts.steps);
        let (names, rows) = exp::run_nlu(&rt, "train", exp::NLU_METHODS, &opts)?;
        let md = exp::render_table("Table 2 analogue: NLU suite", &names, &rows);
        println!("{md}");
        save_result("tab2_nlu", &md)?;
        fig1.push(("nlu".into(), rows));
    }
    if suite == "commonsense" || suite == "all" {
        println!("== Table 3 analogue: commonsense ==");
        let (names, rows) = exp::run_commonsense(&rt, "train", exp::COMMONSENSE_METHODS, &opts)?;
        let md = exp::render_table("Table 3 analogue: commonsense suite", &names, &rows);
        println!("{md}");
        save_result("tab3_commonsense", &md)?;
        fig1.push(("commonsense".into(), rows));
    }
    if suite == "arithmetic" || suite == "all" {
        println!("== Table 4 analogue: arithmetic ==");
        let (names, rows) = exp::run_arithmetic(&rt, "train", exp::ARITHMETIC_METHODS, &opts)?;
        let md = exp::render_table("Table 4 analogue: arithmetic suite", &names, &rows);
        println!("{md}");
        save_result("tab4_arithmetic", &md)?;
        fig1.push(("arithmetic".into(), rows));
    }
    if suite == "instruct" || suite == "all" {
        println!("== Table 5 analogue: instruction following ==");
        let md = exp::run_instruct(&rt, "train", exp::INSTRUCT_METHODS, &opts)?;
        println!("{md}");
        save_result("tab5_instruct", &md)?;
    }
    if suite == "multimodal" || suite == "all" {
        println!("== Table 6 analogue: multimodal ==");
        let (names, rows) = exp::run_multimodal(&rt, "train", exp::MULTIMODAL_METHODS, &opts)?;
        let md = exp::render_table("Table 6 analogue: multimodal suite", &names, &rows);
        println!("{md}");
        save_result("tab6_multimodal", &md)?;
    }
    if suite == "commonsense2" || suite == "all" {
        println!("== Table D.2 analogue: commonsense on backbone 2 ==");
        let (names, rows) = exp::run_commonsense(&rt, "train2", exp::TRAIN2_METHODS, &opts)?;
        let md = exp::render_table("Table D.2 analogue: second backbone", &names, &rows);
        println!("{md}");
        save_result("tabd2_commonsense2", &md)?;
    }
    if fig1.len() == 3 {
        let md = exp::fig1_summary(&fig1[0].1, &fig1[1].1, &fig1[2].1);
        println!("{md}");
        save_result("fig1_summary", &md)?;
    }
    Ok(())
}

fn cmd_pilot(args: &Args) -> Result<()> {
    let study = args.get_or("study", "magnitude-angle");
    let steps = args.usize_or("steps", 100);
    let seed = args.usize_or("seed", 0) as u64;
    let rt = runtime()?;
    match study.as_str() {
        "magnitude-angle" => {
            let mut md = String::from("## Figure 2 (L/M) + B.1 analogue: ΔM / ΔD per layer\n");
            for method in ["full", "lora"] {
                println!("finetuning ({method}) for the representation study...");
                let deltas = pilot::study_magnitude_angle(&rt, "train", method, steps, seed)?;
                md.push_str(&format!("\n### {method} finetuning\n"));
                md.push_str("| layer | ΔM (rel. magnitude) | ΔD (cosine) |\n|---|---|---|\n");
                for d in &deltas {
                    md.push_str(&format!(
                        "| {} | {:.4} | {:.4} |\n",
                        d.layer, d.delta_m, d.delta_d
                    ));
                }
            }
            println!("{md}");
            save_result("fig2_magnitude_angle", &md)?;
        }
        "disentangle" => {
            let suite = tasks::nlu_suite();
            // Four tasks with <= 4 classes (the head's class count):
            // MRPC / CoLA / SST-2 / QNLI analogues.
            let picks = [1usize, 3, 4, 5];
            let mut md = String::from(
                "## Figure 2 (Right) analogue: disentanglement\n| task | normal | mag | angle | random backbone |\n|---|---|---|---|---|\n",
            );
            for &ti in &picks {
                let task = suite[ti].as_ref();
                let mut cells = vec![task.name().to_string()];
                for mode in ["normal", "mag", "angle"] {
                    let r = pilot::study_disentangle(&rt, "train", mode, task, false, steps, seed)?;
                    cells.push(format!("{:.3}", r.score));
                    println!("  {} / {mode}: {:.3}", task.name(), r.score);
                }
                let r = pilot::study_disentangle(&rt, "train", "normal", task, true, steps, seed)?;
                cells.push(format!("{:.3}", r.score));
                md.push_str(&format!("| {} |\n", cells.join(" | ")));
            }
            println!("{md}");
            save_result("fig2_disentangle", &md)?;
        }
        s => bail!("unknown study {s} (magnitude-angle|disentangle)"),
    }
    Ok(())
}

fn cmd_compose(args: &Args) -> Result<()> {
    let steps = args.usize_or("steps", 200);
    let n_eval = args.usize_or("n-eval", 32);
    let seed = args.usize_or("seed", 0) as u64;
    let rt = runtime()?;

    println!("training both subspaces simultaneously ({steps} steps, alternating grad masks)...");
    let out = compose::train_composed(&rt, "train", steps, seed)?;
    println!("final losses: task-A {:.4}, task-B {:.4}", out.loss_a, out.loss_b);

    let econf = EngineConfig {
        model: "train".into(),
        mode: "road".into(),
        decode_slots: 8,
        queue_capacity: 1024,
        ..Default::default()
    };
    let mut engine = Engine::new(rt.clone(), econf)?;
    let task_a = compose::ForeignEcho;
    let task_b = compose::NativeReverse;

    let mut md = String::from("## Figure 5 analogue: subspace composition\n\n");
    md.push_str("| adapter | task-A (foreign echo) EM | task-B (native reverse) EM |\n|---|---|---|\n");
    for (name, adapter) in [
        ("upper-half(A)", &out.adapter_a),
        ("lower-half(B)", &out.adapter_b),
        ("combined", &out.combined),
    ] {
        let sa = compose::score_adapter(&mut engine, name, adapter, &task_a, n_eval, seed ^ 1)?;
        let sb = compose::score_adapter(&mut engine, name, adapter, &task_b, n_eval, seed ^ 2)?;
        println!("{name:<16} A={sa:.3} B={sb:.3}");
        md.push_str(&format!("| {name} | {sa:.3} | {sb:.3} |\n"));
    }

    // Qualitative transcripts (the Fig 5 presentation).
    md.push_str("\n### Qualitative samples (combined adapter)\n```\n");
    let prompts = vec!["g:ab>".to_string(), "i:ab>".to_string()];
    for t in compose::sample_responses(&mut engine, "combined", &prompts, 12)? {
        md.push_str(&format!("{} -> {}\n", t.prompt, t.response));
    }
    md.push_str("```\n");
    println!("{md}");
    save_result("fig5_compose", &md)?;
    Ok(())
}

fn cmd_bench_serving(args: &Args) -> Result<()> {
    let study = args.get_or("study", "hetero");
    let tokens = args.usize_or("tokens", 64);
    let seed = args.usize_or("seed", 7) as u64;
    // The runtime (and its artifacts) is loaded per study: the sched
    // study's --sim-clock path runs on the deterministic harness and
    // needs no artifacts at all.  `--backend ref` runs any study on the
    // artifact-free reference model (slow but always available).
    let backend = backend_flag(args)?;
    let md = match study.as_str() {
        "merge" => {
            let pts = bench::fig4_left(&runtime_for(backend)?, tokens, seed)?;
            bench::render_points("Figure 4 (Left) analogue: merged vs unmerged", &pts)
        }
        "tokens" => {
            let counts: Vec<usize> = vec![16, 32, 64, 128];
            let pts = bench::fig4_middle(&runtime_for(backend)?, &counts, seed)?;
            bench::render_points("Figure 4 (Middle) analogue: throughput vs #generated tokens", &pts)
        }
        "hetero" => {
            let counts: Vec<usize> = vec![1, 2, 4, 8];
            let pts = bench::fig4_right(&runtime_for(backend)?, &counts, tokens, seed)?;
            bench::render_points("Figure 4 (Right) analogue: throughput vs #distinct adapters", &pts)
        }
        "kv" => {
            let pts = bench::kv_residency_comparison(&runtime_for(backend)?, tokens, seed)?;
            bench::render_points("KV residency: device-resident vs host-roundtrip decode", &pts)
        }
        "bank" => {
            let n_adapters = args.usize_or("adapters", 64);
            let bank_slots = args.usize_or("bank-slots", 4);
            let n_requests = args.usize_or("requests", n_adapters * 2);
            let pts = bench::bank_churn_study(
                &runtime_for(backend)?,
                n_adapters,
                bank_slots,
                n_requests,
                tokens,
                seed,
            )?;
            bench::render_bank_points(
                "Adapter-bank churn: paged per-slot uploads vs whole-bank baseline",
                &pts,
            )
        }
        "stream" => {
            let n_requests = args.usize_or("requests", 16);
            let cancel_after = args.usize_or("cancel-after", tokens / 4);
            // --sim-clock drives the open-loop arrivals on a shared manual
            // clock: no sleeps, the whole arrival schedule is a virtual jump.
            let clock = if args.bool("sim-clock") {
                road::util::clock::Clock::manual()
            } else {
                road::util::clock::Clock::wall()
            };
            // The study drives the threaded server, which owns its own runtime.
            let pts = bench::streaming_study(
                road::Manifest::default_dir(),
                "serve",
                n_requests,
                tokens,
                cancel_after.max(1),
                seed,
                clock,
                backend,
            )?;
            bench::render_streaming_points(
                "Open-loop streaming: observed TTFT and cancellation reclaim",
                &pts,
            )
        }
        "sched" => {
            let n_requests = args.usize_or("requests", 160);
            let distinct = args.usize_or("adapters", 12);
            // Scheduling contrast wants saturation, not long generations;
            // default shorter than the throughput studies.
            let new_tokens = if args.get("tokens").is_some() { tokens } else { 32 };
            let sim = args.bool("sim-clock");
            let pts = if sim {
                // Deterministic harness on the virtual clock: no
                // artifacts, no sleeps, byte-identical output across runs.
                bench::sched_study_sim(n_requests, distinct, new_tokens, seed)
            } else {
                bench::sched_study_engine(
                    &runtime_for(backend)?,
                    n_requests,
                    distinct,
                    new_tokens,
                    seed,
                )?
            };
            let json = bench::sched_points_json(&pts).to_string_pretty();
            if sim {
                // Only the deterministic harness commits a JSON artifact:
                // CI runs the study twice and byte-diffs this file.
                std::fs::create_dir_all("results")?;
                std::fs::write("results/BENCH_sched.json", format!("{json}\n"))?;
                println!("[saved results/BENCH_sched.json]");
            }
            let mut md = bench::render_sched_points(
                "Admission scheduling: fcfs vs edf vs priority vs fair-share",
                &pts,
            );
            md.push_str("\n```json\n");
            md.push_str(&json);
            md.push_str("\n```\n");
            md
        }
        "kvpage" => {
            let n_requests = args.usize_or("requests", 48);
            // Short generations on the tiny model: the study measures block
            // accounting and admission, not decode throughput.
            let new_tokens = if args.get("tokens").is_some() { tokens } else { 16 };
            let budgets: Vec<usize> = vec![32, 64, 128, 256];
            // --sim-clock runs on the artifact-free reference model; every
            // recorded number is integer accounting on a virtual clock, so
            // two runs are byte-identical (CI diffs them).
            let rt = if args.bool("sim-clock") {
                Rc::new(Runtime::reference())
            } else {
                runtime_for(backend)?
            };
            let pts = bench::kvpage_study(&rt, n_requests, new_tokens, &budgets, seed)?;
            let json = bench::kvpage_points_json(&pts).to_string_pretty();
            std::fs::create_dir_all("results")?;
            std::fs::write("results/BENCH_kvpage.json", format!("{json}\n"))?;
            println!("[saved results/BENCH_kvpage.json]");
            let mut md = bench::render_kvpage_points(
                "Paged KV: shared-prefix reuse and admission vs flat accounting",
                &pts,
            );
            md.push_str("\n```json\n");
            md.push_str(&json);
            md.push_str("\n```\n");
            md
        }
        "router" => {
            let n_requests = args.usize_or("requests", 96);
            let replicas = args.usize_or("replicas", 3);
            // Placement contrast wants paging pressure, not long
            // generations; default short.
            let new_tokens = if args.get("tokens").is_some() { tokens } else { 8 };
            // The study always runs on the deterministic fleet sim
            // (lockstep manual clocks, integer accounting): --sim-clock is
            // accepted for symmetry with the other studies, and two runs
            // are byte-identical either way (CI diffs the JSON).
            let pts = bench::router_study_sim(n_requests, replicas, new_tokens, seed);
            let json = bench::router_points_json(&pts).to_string_pretty();
            std::fs::create_dir_all("results")?;
            std::fs::write("results/BENCH_router.json", format!("{json}\n"))?;
            println!("[saved results/BENCH_router.json]");
            let mut md = bench::render_router_points(
                "Fleet placement: adapter-affinity vs least-loaded vs round-robin",
                &pts,
            );
            md.push_str("\n```json\n");
            md.push_str(&json);
            md.push_str("\n```\n");
            md
        }
        "adapters" => {
            // The fused-epilogue head-to-head always runs on the reference
            // backend with a manual clock and an analytic cost model, so
            // two runs are byte-identical (CI diffs the JSON against the
            // committed artifact).
            let rt = Rc::new(Runtime::reference());
            let pts = bench::adapters_study(&rt, seed)?;
            let json = bench::adapters_points_json(&pts).to_string_pretty();
            std::fs::create_dir_all("results")?;
            std::fs::write("results/BENCH_adapters.json", format!("{json}\n"))?;
            println!("[saved results/BENCH_adapters.json]");
            let mut md = bench::render_adapters_points(
                "Adapter epilogues: fused RoAd vs LoRA-bmm vs ia3 across hetero batches",
                &pts,
            );
            md.push_str("\n```json\n");
            md.push_str(&json);
            md.push_str("\n```\n");
            md
        }
        s => bail!("unknown study {s} (merge|tokens|hetero|kv|bank|stream|sched|kvpage|router|adapters)"),
    };
    println!("{md}");
    save_result(&format!("fig4_{study}"), &md)?;
    Ok(())
}

fn cmd_bench_train(args: &Args) -> Result<()> {
    let iters = args.usize_or("iters", 50);
    let rt = runtime()?;
    let methods = ["oft16", "oft2", "road1", "road2", "road4", "lora", "ia3"];
    let mut rows = Vec::new();
    for m in methods {
        println!("timing {m} ({iters} iters)...");
        rows.push(bench::measure_train_efficiency(&rt, "train", m, iters, 3)?);
    }
    let md = bench::render_train_efficiency(&rows);
    println!("{md}");
    save_result("tabd1_train_efficiency", &md)?;
    Ok(())
}

fn cmd_verify() -> Result<()> {
    let rt = runtime()?;
    let golden: Vec<String> = rt.manifest.golden.keys().cloned().collect();
    for name in &golden {
        let exe = rt.load(name)?;
        let (ins, want) = rt.load_golden(name)?;
        let refs: Vec<&road::tensor::HostTensor> = ins.iter().collect();
        let outs = exe.run_host(&refs)?;
        for (got, want) in outs.iter().zip(&want) {
            if want.dtype == road::tensor::DType::F32 {
                road::runtime::allclose(got, want, 2e-4, 2e-5)
                    .with_context(|| format!("golden mismatch in {name}"))?;
            }
        }
        println!("golden OK: {name}");
    }
    println!("all {} golden records verified", golden.len());
    Ok(())
}
