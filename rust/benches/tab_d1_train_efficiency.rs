//! Table D.1 bench: finetuning efficiency per method — step time and
//! trainable/optimizer-state footprint.  RoAd's inherently-orthogonal 2x2
//! rotations vs OFT's per-step Cayley matrix solves.
//!
//! ```bash
//! cargo bench --bench tab_d1_train_efficiency
//! cargo bench --bench tab_d1_train_efficiency -- quick
//! ```

use std::rc::Rc;

use road::bench;
use road::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    if !road::Manifest::available_or_note() {
        return Ok(());
    }
    let quick = std::env::args().any(|a| a == "quick");
    let iters = if quick { 10 } else { 50 };
    let rt = Rc::new(Runtime::from_default_artifacts()?);

    // The paper's Tab D.1 rows: OFT at two block granularities vs the
    // three RoAd variants (plus lora/ia3 for context).
    let methods = ["oft16", "oft2", "road1", "road2", "road4", "lora", "ia3"];
    let mut rows = Vec::new();
    for m in methods {
        eprintln!("timing {m} ({iters} iters)...");
        rows.push(bench::measure_train_efficiency(&rt, "train", m, iters, 3)?);
    }
    println!("{}", bench::render_train_efficiency(&rows));

    // Headline comparison: the paper reports OFT (w=2 analogue) ~50x the
    // RoAd step time; on XLA-CPU the Cayley solves partially fuse, so the
    // expected shape is oft >= road with the gap growing for oft16.
    let t = |name: &str| rows.iter().find(|r| r.method == name).unwrap().secs_per_step;
    println!(
        "step-time ratios: oft2/road1 = {:.2}x, oft16/road1 = {:.2}x, lora/road1 = {:.2}x",
        t("oft2") / t("road1"),
        t("oft16") / t("road1"),
        t("lora") / t("road1"),
    );
    Ok(())
}
