//! Offline stand-in for the `xla` (xla-rs) PJRT bindings.
//!
//! The build image bakes no `xla_extension` native library, so this path
//! crate supplies the API surface the `road` runtime compiles against:
//!
//! * **Functional**: client construction, host→"device" uploads
//!   ([`PjRtClient::buffer_from_host_buffer`]), "device"→host downloads
//!   ([`PjRtBuffer::to_literal_sync`]), literal decomposition.  Buffers are
//!   host-memory blocks behind `Rc` handles, so upload/download carry real
//!   memcpy cost and handle moves are free — the same cost *ordering* as a
//!   real PJRT device, which keeps the coordinator's transfer-avoidance
//!   logic observable (and benchmarkable) without hardware.
//! * **Stubbed**: [`PjRtLoadedExecutable::execute_b`] /
//!   [`PjRtLoadedExecutable::execute_untupled`] return an error — running
//!   HLO needs the native runtime.  Integration tests that execute
//!   artifacts skip when artifacts are absent, and fail with this error if
//!   artifacts exist but the native runtime does not.
//!
//! Swapping in the real bindings is a Cargo.toml change: replace the
//! `vendor/xla` path dependency with `xla-rs` + `xla_extension`, and
//! provide `execute_untupled` as `execute` with
//! `ExecuteOptions::untuple_result = true`.

use std::fmt;
use std::rc::Rc;

/// Error type for all stub operations (`Debug`-formatted by callers).
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

/// Scalar types that can cross the host/buffer boundary.
pub trait NativeType: Copy + Default + 'static {
    const PRIM: PrimitiveType;
}

impl NativeType for f32 {
    const PRIM: PrimitiveType = PrimitiveType::F32;
}

impl NativeType for i32 {
    const PRIM: PrimitiveType = PrimitiveType::S32;
}

fn to_bytes<T: NativeType>(values: &[T]) -> Vec<u8> {
    let n = std::mem::size_of_val(values);
    let mut out = vec![0u8; n];
    // SAFETY: T is a plain scalar; lengths match by construction.
    unsafe {
        std::ptr::copy_nonoverlapping(values.as_ptr() as *const u8, out.as_mut_ptr(), n);
    }
    out
}

fn from_bytes<T: NativeType>(bytes: &[u8]) -> Vec<T> {
    let n = bytes.len() / std::mem::size_of::<T>();
    let mut out = vec![T::default(); n];
    // SAFETY: out has exactly n elements; T accepts any bit pattern.
    unsafe {
        std::ptr::copy_nonoverlapping(
            bytes.as_ptr(),
            out.as_mut_ptr() as *mut u8,
            n * std::mem::size_of::<T>(),
        );
    }
    out
}

struct BufferData {
    prim: PrimitiveType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
}

/// A "device" buffer: host memory behind a cheap handle.  Like the real
/// binding, it is single-threaded (`Rc`) and not clonable by value — moving
/// a `PjRtBuffer` moves the handle, not the payload.
pub struct PjRtBuffer {
    data: Rc<BufferData>,
}

impl PjRtBuffer {
    /// Download: copies the payload out (the expensive direction).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal {
            prim: self.data.prim,
            dims: self.data.dims.clone(),
            bytes: self.data.bytes.clone(),
            tuple: None,
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.data.dims
    }

    pub fn element_count(&self) -> usize {
        self.data.dims.iter().product::<i64>().max(1) as usize
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side value: either one array or a tuple of literals.
pub struct Literal {
    prim: PrimitiveType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    pub fn array_shape(&self) -> Result<ArrayShape> {
        if self.tuple.is_some() {
            return Err(XlaError("array_shape on a tuple literal".into()));
        }
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(XlaError("to_vec on a tuple literal".into()));
        }
        if self.prim != T::PRIM {
            return Err(XlaError(format!(
                "literal is {:?}, requested {:?}",
                self.prim,
                T::PRIM
            )));
        }
        Ok(from_bytes(&self.bytes))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple.ok_or_else(|| XlaError("to_tuple on an array literal".into()))
    }
}

/// Parsed HLO module (the stub only checks the artifact is readable; the
/// native binding reparses instruction ids from the text form).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text_len: usize,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text_len: text.len() })
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    fn unavailable<T>() -> Result<T> {
        Err(XlaError(
            "HLO execution needs the native PJRT runtime (xla_extension); \
             this build uses the offline host-memory stub — swap the \
             vendor/xla path dependency for xla-rs to execute artifacts"
                .into(),
        ))
    }

    /// Execute with a tuple root; `result[0][0]` is the tuple buffer.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Self::unavailable()
    }

    /// Execute with `untuple_result`: one device buffer per output, never
    /// materialized on host.  (On the native binding: `execute` with
    /// `ExecuteOptions::untuple_result = true`.)
    pub fn execute_untupled(&self, _args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        Self::unavailable()
    }
}

/// Handle to the (stub) CPU platform.  Cheap to clone, not `Send` — same
/// contract as the real `Rc`-based client.
#[derive(Clone)]
pub struct PjRtClient {
    _not_send: Rc<()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _not_send: Rc::new(()) })
    }

    /// Upload: copies host data into a fresh buffer (the expensive
    /// direction).
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let n: usize = dims.iter().product::<usize>().max(1);
        if n != data.len() {
            return Err(XlaError(format!(
                "host buffer has {} elements, shape {dims:?} wants {n}",
                data.len()
            )));
        }
        Ok(PjRtBuffer {
            data: Rc::new(BufferData {
                prim: T::PRIM,
                dims: dims.iter().map(|&d| d as i64).collect(),
                bytes: to_bytes(data),
            }),
        })
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upload_download_roundtrip() {
        let c = PjRtClient::cpu().unwrap();
        let b = c.buffer_from_host_buffer(&[1.0f32, 2.0, 3.0, 4.0], &[2, 2], None).unwrap();
        assert_eq!(b.dims(), &[2, 2]);
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_buffer(&[1i32, 2], &[3], None).is_err());
    }

    #[test]
    fn execution_is_unavailable() {
        let exe = PjRtLoadedExecutable;
        assert!(exe.execute_b(&[]).is_err());
        assert!(exe.execute_untupled(&[]).is_err());
    }
}
