//! Rule registry, the [`Finding`] type, and the escape-hatch filter.
//!
//! Every rule is a function from a scanned repo to findings.  A finding
//! survives unless the flagged line (or the line above it) carries a
//! justified escape:
//!
//! ```text
//! // roadlint: allow(clock-discipline) -- wall-time profiling of real
//! // hardware execution; no virtual-time replay path runs through here.
//! ```
//!
//! The justification (any text after `allow(<rule>)`, conventionally
//! introduced with `--`) is mandatory: a bare `allow` is itself a
//! finding, so silencing a rule always costs a written rationale that
//! reviewers and future sessions can audit.

pub mod artifact_budget;
pub mod channels;
pub mod clock;
pub mod panic_free;
pub mod sleep;
pub mod typed_errors;

use crate::scanner::SourceFile;

/// Everything the rules see: the scanned sources plus the docs that
/// drift rules cross-check against.
pub struct RepoContext {
    pub files: Vec<SourceFile>,
    /// docs/DESIGN.md content ("" when absent — the typed-error rule then
    /// reports every wire string as undocumented).
    pub design_md: String,
}

/// One rule violation, pointing at `path:line`.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub rule: &'static str,
    /// Repo-relative `/`-separated path.
    pub path: String,
    /// 1-indexed; 0 for repo-level findings with no single site.
    pub line: usize,
    pub message: String,
}

/// A registered rule: stable name (the `allow(...)` key) + checker.
pub struct RuleDef {
    pub name: &'static str,
    pub description: &'static str,
    pub check: fn(&RepoContext) -> Vec<Finding>,
}

/// The registry, in reporting order.  Adding a rule = adding a row here
/// (and a fixture pair under `tests/fixtures/`).
pub fn registry() -> Vec<RuleDef> {
    vec![
        RuleDef {
            name: clock::NAME,
            description: "no Instant::now()/SystemTime::now() outside util/clock.rs \
                          (wall time must be injectable for deterministic replay)",
            check: clock::check,
        },
        RuleDef {
            name: sleep::NAME,
            description: "no thread::sleep in rust/src/bench or rust/tests \
                          (benches and tests pace on the virtual clock)",
            check: sleep::check,
        },
        RuleDef {
            name: artifact_budget::NAME,
            description: "require_artifacts!() call sites are budgeted so coverage \
                          cannot drain back behind the artifact gate",
            check: artifact_budget::check,
        },
        RuleDef {
            name: panic_free::NAME,
            description: "no unwrap/expect/panic! in non-test coordinator or epilogue-kernel \
                          code (a malformed peer or lost invariant must not kill a serving \
                          thread)",
            check: panic_free::check,
        },
        RuleDef {
            name: typed_errors::NAME,
            description: "no Result<_, String> in coordinator code; every EngineError::kind() \
                          wire string must appear in docs/DESIGN.md",
            check: typed_errors::check,
        },
        RuleDef {
            name: channels::NAME,
            description: "no unbounded mpsc::channel() in net.rs/server.rs without a \
                          justified escape (flow control is a stated invariant)",
            check: channels::check,
        },
    ]
}

/// Run every rule and apply the escape-hatch filter.
pub fn run_all(ctx: &RepoContext) -> Vec<Finding> {
    let mut out = Vec::new();
    for rule in registry() {
        let raw = (rule.check)(ctx);
        out.extend(apply_allows(ctx, rule.name, raw));
    }
    out
}

/// Filter findings through `// roadlint: allow(<rule>)` directives, and
/// convert unjustified directives into findings of their own.
fn apply_allows(ctx: &RepoContext, rule: &'static str, raw: Vec<Finding>) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in raw {
        match allow_at(ctx, rule, &f.path, f.line) {
            Allow::Justified => {}
            Allow::Unjustified(dir_line) => out.push(Finding {
                rule,
                path: f.path.clone(),
                line: dir_line,
                message: format!(
                    "roadlint: allow({rule}) needs a justification — \
                     write `// roadlint: allow({rule}) -- <why this site is exempt>`"
                ),
            }),
            Allow::None => out.push(f),
        }
    }
    out
}

enum Allow {
    None,
    Justified,
    /// Directive present but bare; carries the directive's line.
    Unjustified(usize),
}

/// Look for an `allow(<rule>)` directive covering `line` (1-indexed): on
/// the line itself, or on an immediately preceding run of comment-only
/// lines (so a directive + multi-line justification block above the
/// flagged statement works).
fn allow_at(ctx: &RepoContext, rule: &str, path: &str, line: usize) -> Allow {
    let Some(file) = ctx.files.iter().find(|f| f.rel == path) else {
        return Allow::None;
    };
    if line == 0 || line > file.lines.len() {
        return Allow::None;
    }
    let mut candidates = vec![line - 1];
    // Walk up through comment-only lines above the flagged one.
    let mut i = line - 1;
    while i > 0 {
        i -= 1;
        let l = &file.lines[i];
        if l.code.trim().is_empty() && !l.comment.is_empty() {
            candidates.push(i);
        } else {
            break;
        }
    }
    for &idx in &candidates {
        let comment = &file.lines[idx].comment;
        let needle = format!("roadlint: allow({rule})");
        if let Some(pos) = comment.find(&needle) {
            let mut rest = comment[pos + needle.len()..].trim().to_string();
            // The justification may continue on following comment lines.
            let mut j = idx + 1;
            while j < line - 1 {
                rest.push(' ');
                rest.push_str(file.lines[j].comment.trim());
                j += 1;
            }
            let just: String =
                rest.chars().filter(|c| c.is_alphanumeric() || c.is_whitespace()).collect();
            if just.split_whitespace().count() >= 3 {
                return Allow::Justified;
            }
            return Allow::Unjustified(idx + 1);
        }
    }
    Allow::None
}

/// Shared matcher: every occurrence of `needle` in a line's code view.
/// When the needle starts with an identifier character, the preceding
/// character must not be part of an identifier (so `sync_channel()`
/// never matches a `channel()` needle); needles that start with
/// punctuation (`.unwrap()`) are naturally glued to their receiver and
/// skip that check.
pub fn code_matches(code: &str, needle: &str) -> Vec<usize> {
    let needs_boundary =
        needle.chars().next().is_some_and(|c| c.is_alphanumeric() || c == '_');
    let mut hits = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find(needle) {
        let at = from + pos;
        let boundary = !needs_boundary
            || at == 0
            || !code[..at].chars().next_back().is_some_and(|c| c.is_alphanumeric() || c == '_');
        if boundary {
            hits.push(at);
        }
        from = at + needle.len();
    }
    hits
}

#[cfg(test)]
mod tests {
    use super::code_matches;

    #[test]
    fn ident_needles_respect_identifier_boundaries() {
        assert_eq!(code_matches("let (a, b) = channel();", "channel()"), vec![13]);
        assert!(code_matches("let (a, b) = sync_channel(1);", "channel()").is_empty());
        assert!(code_matches("let (a, b) = sync_channel::<u32>(1);", "channel::<").is_empty());
    }

    #[test]
    fn punctuation_needles_match_after_their_receiver() {
        assert_eq!(code_matches("v.unwrap()", ".unwrap()"), vec![1]);
        assert_eq!(code_matches("x.expect(\"\")", ".expect("), vec![1]);
        assert_eq!(code_matches("a.unwrap().b.unwrap()", ".unwrap()").len(), 2);
    }
}
