//! Request/response/event types for the multi-adapter serving engine.

use std::time::{Duration, Instant};

use super::queue::EngineError;

#[derive(Clone, Debug)]
pub struct SamplingParams {
    /// 0.0 => greedy decoding.
    pub temperature: f32,
    /// 0 => no top-k truncation.
    pub top_k: usize,
    pub seed: u64,
    /// Stop early when this token is produced (it is not emitted).
    pub stop_token: Option<i32>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, seed: 0, stop_token: None }
    }
}

#[derive(Clone, Debug)]
pub struct Request {
    /// Engine-issued request id: [`super::engine::Engine::submit`] assigns
    /// the next id unconditionally, so any value set here is overwritten.
    /// Callers correlate submissions through the id `submit` returns (or
    /// [`super::server::Generation::id`]), never by stamping their own.
    pub id: u64,
    /// Registered adapter name; None = base model (identity slot 0).
    pub adapter: Option<String>,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
    /// Stamped by `Engine::submit` at enqueue time and carried through the
    /// admission queue so TTFT/e2e include queueing delay.  `None` until
    /// submitted.
    pub submitted_at: Option<Instant>,
    /// Per-request deadline, measured from `submitted_at`.  Expired
    /// requests are shed from the queue at admission and reaped from their
    /// decode slot between steps, producing
    /// [`EngineError::DeadlineExceeded`] on the event stream.
    pub deadline: Option<Duration>,
    /// Scheduling tier for the `priority` admission policy: higher values
    /// admit first, FIFO within a tier.  The other policies ignore it.
    pub priority: u8,
}

impl Request {
    pub fn new(prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request {
            id: 0,
            adapter: None,
            prompt,
            max_new_tokens,
            sampling: Default::default(),
            submitted_at: None,
            deadline: None,
            priority: 0,
        }
    }

    pub fn with_adapter(mut self, name: &str) -> Request {
        self.adapter = Some(name.to_string());
        self
    }

    pub fn with_sampling(mut self, s: SamplingParams) -> Request {
        self.sampling = s;
        self
    }

    /// Give the request `d` of budget from submission; see
    /// [`Request::deadline`].
    pub fn with_deadline(mut self, d: Duration) -> Request {
        self.deadline = Some(d);
        self
    }

    /// Scheduling tier (see [`Request::priority`]): higher admits first
    /// under the `priority` policy.
    pub fn with_priority(mut self, p: u8) -> Request {
        self.priority = p;
        self
    }

    /// Whether the deadline has passed as of `now`.  Never true for
    /// requests without a deadline or not yet submitted.
    pub fn expired(&self, now: Instant) -> bool {
        match (self.submitted_at, self.deadline) {
            (Some(s), Some(d)) => now.checked_duration_since(s).is_some_and(|e| e > d),
            _ => false,
        }
    }

    /// Absolute deadline (`submitted_at + deadline`) — the EDF policy's
    /// sort key.  `None` until submitted, or when the request has no
    /// deadline.
    pub fn deadline_at(&self) -> Option<Instant> {
        match (self.submitted_at, self.deadline) {
            (Some(s), Some(d)) => Some(s + d),
            _ => None,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopToken,
    Cancelled,
}

impl FinishReason {
    /// Wire name (NDJSON protocol, docs/DESIGN.md §Streaming protocol).
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::StopToken => "stop_token",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

#[derive(Clone, Debug)]
pub struct RequestOutput {
    /// Engine-issued id (see [`Request::id`]).
    pub id: u64,
    pub adapter: Option<String>,
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// Time to first token (seconds).
    pub ttft: f64,
    /// End-to-end latency (seconds).
    pub e2e: f64,
}

/// One event on a request's stream, emitted from inside
/// [`super::engine::Engine::step`] as lanes advance.
///
/// Per-request event grammar (docs/DESIGN.md §Streaming protocol):
///
/// ```text
/// Admitted  Token*  (Finished | Error)        — admitted requests
/// (Finished | Error)                          — cancelled/shed in queue
/// ```
///
/// `Finished`/`Error` are terminal; the concatenation of `Token` payloads
/// is exactly `Finished`'s `RequestOutput::tokens` (stop tokens are never
/// emitted as `Token` events, matching their absence from the output).
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// The request left the admission queue and entered a prefill batch.
    Admitted { id: u64 },
    /// One generated token.  `pos` is the token's index in the generated
    /// sequence (0-based); `ttft_hint` is the submit→first-token latency in
    /// seconds, present on the first token only.
    Token { id: u64, token: i32, pos: usize, ttft_hint: Option<f64> },
    /// Terminal: the request completed (including `FinishReason::Cancelled`
    /// for cancellations that reclaimed a decode slot).
    Finished(RequestOutput),
    /// Terminal: the request died with a typed error (deadline shed,
    /// engine shutdown).
    Error { id: u64, error: EngineError },
}

impl StreamEvent {
    pub fn id(&self) -> u64 {
        match self {
            StreamEvent::Admitted { id } | StreamEvent::Token { id, .. } => *id,
            StreamEvent::Finished(out) => out.id,
            StreamEvent::Error { id, .. } => *id,
        }
    }

    /// Terminal events end the request's stream.
    pub fn is_terminal(&self) -> bool {
        matches!(self, StreamEvent::Finished(_) | StreamEvent::Error { .. })
    }
}

/// In-flight request state pinned to a decode slot.
#[derive(Debug)]
pub struct ActiveRequest {
    pub req: Request,
    pub slot_adapter: usize,
    pub generated: Vec<i32>,
    pub pos: usize,
    pub submitted: Instant,
    pub first_token_at: Option<Instant>,
    /// When the lane's most recent generated token was sampled — the
    /// anchor for the inter-token-latency (ITL) recorder.
    pub last_token_at: Option<Instant>,
    /// Chunked-admission cold lanes carry no prefill output to publish
    /// from; instead the engine publishes the lane's prompt prefix into
    /// the shared-prefix cache once feeding completes.  Cleared after the
    /// publish (and never set on prefix-hit or bucketed-prefill lanes).
    pub publish_on_fed: bool,
    pub rng_state: crate::util::rng::Rng,
}

impl ActiveRequest {
    /// `admitted` is when the scheduler pulled the request into a prefill
    /// batch; `submitted` is taken from the request's submit stamp when
    /// present, so latency metrics start the clock at the front door
    /// (queue wait included), not at admission.
    pub fn new(req: Request, slot_adapter: usize, admitted: Instant) -> ActiveRequest {
        let seed = req.sampling.seed ^ req.id.wrapping_mul(0x9e3779b97f4a7c15);
        ActiveRequest {
            slot_adapter,
            pos: req.prompt.len(),
            generated: Vec::with_capacity(req.max_new_tokens),
            submitted: req.submitted_at.unwrap_or(admitted),
            first_token_at: None,
            last_token_at: None,
            publish_on_fed: false,
            rng_state: crate::util::rng::Rng::seed_from(seed),
            req,
        }
    }

    pub fn done(&self) -> Option<FinishReason> {
        if let (Some(stop), Some(&last)) = (self.req.sampling.stop_token, self.generated.last()) {
            if last == stop {
                return Some(FinishReason::StopToken);
            }
        }
        if self.generated.len() >= self.req.max_new_tokens {
            return Some(FinishReason::MaxTokens);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_expiry_requires_submission() {
        let now = Instant::now();
        let r = Request::new(vec![1], 4).with_deadline(Duration::ZERO);
        assert!(!r.expired(now), "unsubmitted requests never expire");
        let mut r = r;
        r.submitted_at = Some(now - Duration::from_millis(5));
        assert!(r.expired(now), "elapsed 5ms > 0ms budget");
        r.deadline = Some(Duration::from_secs(60));
        assert!(!r.expired(now));
        r.deadline = None;
        assert!(!r.expired(now), "no deadline, no expiry");
    }

    #[test]
    fn stream_event_ids_and_terminality() {
        let fin = StreamEvent::Finished(RequestOutput {
            id: 7,
            adapter: None,
            tokens: vec![],
            finish: FinishReason::Cancelled,
            ttft: 0.0,
            e2e: 0.0,
        });
        assert_eq!(fin.id(), 7);
        assert!(fin.is_terminal());
        let tok = StreamEvent::Token { id: 3, token: 9, pos: 0, ttft_hint: Some(0.1) };
        assert_eq!(tok.id(), 3);
        assert!(!tok.is_terminal());
        assert!(!StreamEvent::Admitted { id: 3 }.is_terminal());
        let err = StreamEvent::Error { id: 4, error: EngineError::DeadlineExceeded };
        assert!(err.is_terminal());
        assert_eq!(err.id(), 4);
    }

    #[test]
    fn priority_and_absolute_deadline_builders() {
        let r = Request::new(vec![1], 4);
        assert_eq!(r.priority, 0, "default tier");
        assert_eq!(r.deadline_at(), None, "no deadline, no absolute deadline");
        let mut r = Request::new(vec![1], 4)
            .with_priority(7)
            .with_deadline(Duration::from_millis(40));
        assert_eq!(r.priority, 7);
        assert_eq!(r.deadline_at(), None, "unsubmitted requests have no absolute deadline");
        let t = Instant::now();
        r.submitted_at = Some(t);
        assert_eq!(r.deadline_at(), Some(t + Duration::from_millis(40)));
    }

    #[test]
    fn finish_reason_wire_names() {
        assert_eq!(FinishReason::MaxTokens.as_str(), "max_tokens");
        assert_eq!(FinishReason::StopToken.as_str(), "stop_token");
        assert_eq!(FinishReason::Cancelled.as_str(), "cancelled");
    }
}
